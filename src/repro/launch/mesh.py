"""Production meshes for the TPU v5e target.

Functions, not module constants: importing this module never touches jax
device state. The dry-run (launch/dryrun.py) sets
``--xla_force_host_platform_device_count=512`` before calling these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool = False):
    """Axes the global batch shards over."""
    return ("pod", "data") if multi_pod else ("data",)


MODEL_AXIS = "model"
TP = 16
