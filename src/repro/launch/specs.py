"""ShapeDtypeStruct input stand-ins + sharding assembly for every
(arch x input-shape x mesh) dry-run combination. No device allocation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models import parallel_ctx, shardings
from repro.training import optimizer
from repro.training.train_step import make_train_step

SDS = jax.ShapeDtypeStruct

# full-attention archs run long_500k only as a documented sliding-window
# variant (DESIGN.md "Shape skips"); whisper-base skips it entirely.
SWA_OVERRIDE_WINDOW = 8192
LONG_SKIP = {"whisper-base"}


def resolve_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if arch in LONG_SKIP:
            raise ValueError(f"{arch} skips long_500k (see DESIGN.md)")
        sub_quadratic = cfg.family in ("hybrid", "ssm") or cfg.swa_window
        if not sub_quadratic:
            cfg = dataclasses.replace(cfg, swa_window=SWA_OVERRIDE_WINDOW)
    return cfg


def token_struct(cfg: ModelConfig, shape: InputShape):
    """Batch dict of ShapeDtypeStructs (text tokens + modality stubs)."""
    b = shape.global_batch
    s = shape.seq_len
    batch = {}
    if shape.kind == "decode":
        pass
    else:
        st = s - cfg.num_image_tokens
        batch["tokens"] = SDS((b, st), jnp.int32)
        if cfg.num_image_tokens:
            batch["image_embeds"] = SDS((b, cfg.num_image_tokens,
                                         cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.is_encoder_decoder:
            batch["enc_frames"] = SDS((b, cfg.encoder_seq_len, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    return batch


def axis_size(mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def batch_specs(cfg: ModelConfig, shape: InputShape, multi_pod: bool,
                mesh=None):
    d = mesh_lib.data_axes(multi_pod)
    b = shape.global_batch
    if mesh is not None:
        nd = 1
        for ax in d:
            nd *= axis_size(mesh, ax)
    else:
        nd = 32 if multi_pod else 16
    bspec = d if b % nd == 0 else (None if b < nd else d[-1])
    specs = {}
    if shape.kind != "decode":
        specs["tokens"] = P(bspec, None)
        if cfg.num_image_tokens:
            specs["image_embeds"] = P(bspec, None, None)
        if cfg.is_encoder_decoder:
            specs["enc_frames"] = P(bspec, None, None)
    return specs, bspec


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(partial(M.init_params, cfg), jax.random.PRNGKey(0))


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(partial(M.init_cache, cfg, batch, max_len))


def _with_ctx(fn, mesh, multi_pod):
    """Give the model code the ambient mesh at trace time (shard_map MoE)."""
    def wrapped(*a):
        with parallel_ctx.use_mesh(mesh, mesh_lib.data_axes(multi_pod),
                                   mesh_lib.MODEL_AXIS):
            return fn(*a)
    return wrapped


def build_dryrun(arch: str, shape_name: str, mesh, multi_pod: bool):
    """Returns (step_fn, arg structs tuple, in_shardings tuple, donate)."""
    cfg = resolve_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    tp = axis_size(mesh, mesh_lib.MODEL_AXIS)

    pstruct = params_struct(cfg)
    pspec = shardings.param_specs(cfg, pstruct, tp=tp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
    bstruct = token_struct(cfg, shape)
    bspec, bax = batch_specs(cfg, shape, multi_pod, mesh)
    bsh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}

    if shape.kind == "train":
        opt_cfg = optimizer.AdamWConfig()
        ostruct = jax.eval_shape(partial(optimizer.init), pstruct)
        osh = optimizer.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspec),
            nu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspec))
        fn = _with_ctx(make_train_step(cfg, opt_cfg), mesh, multi_pod)
        return (fn, (pstruct, ostruct, bstruct), (psh, osh, bsh),
                {"donate_argnums": (0, 1)}, cfg)

    if shape.kind == "prefill":
        cstruct = cache_struct(cfg, shape.global_batch, shape.seq_len)
        cspec = shardings.cache_specs(cfg, cstruct, tp=tp, data_axis=bax)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec)

        def prefill_fn(params, batch, cache):
            return M.prefill(cfg, params, batch, cache)

        return (_with_ctx(prefill_fn, mesh, multi_pod),
                (pstruct, bstruct, cstruct), (psh, bsh, csh),
                {"donate_argnums": (2,)}, cfg)

    # decode: one new token against a cache of seq_len
    b = shape.global_batch
    cstruct = cache_struct(cfg, b, shape.seq_len)
    shard_seq = (b == 1)        # long_500k: context parallelism over data
    cspec = shardings.cache_specs(cfg, cstruct, tp=tp, data_axis=bax,
                                  shard_seq_over_data=shard_seq,
                                  seq_over_model_if_kv_replicated=True)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec)
    tstruct = SDS((b,), jnp.int32)
    tsh = NamedSharding(mesh, P(bax if b > 1 else None))
    posst = SDS((), jnp.int32)
    possh = NamedSharding(mesh, P())

    def decode_fn(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos)

    return (_with_ctx(decode_fn, mesh, multi_pod),
            (pstruct, tstruct, cstruct, posst),
            (psh, tsh, csh, possh), {"donate_argnums": (2,)}, cfg)
