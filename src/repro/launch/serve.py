"""Serving driver: schedule a heterogeneous pool, build the asymmetric
pipeline engine, and serve a Poisson workload end to end.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --reduced --cluster case_study --rate 2 --duration 5 --deadline 30

The scheduler plans for the FULL model on the chosen GPU pool (the paper's
setting); execution on this CPU container runs the --reduced variant of the
same architecture through the scheduled stage layout, preserving every
structural property (stage count, TP degrees, layer ratios).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.core.scheduler import schedule
from repro.serving.engine import InferenceEngine
from repro.serving.request import shared_prefix_workload, synth_workload

CLUSTERS = {
    "case_study": cl.case_study_cluster,
    "half_price": cl.hetero_half_price,
    "full_price": cl.hetero_full_price,
    "homogeneous": cl.homogeneous_a100,
    "tpu_mixed": cl.tpu_mixed_slices,
}


def scale_assignment(asg: Assignment, full_layers: int,
                     run_layers: int) -> Assignment:
    """Project a full-model layer split onto the reduced layer count,
    keeping stage proportions (>=1 layer per stage; stages collapse if the
    reduced model has fewer layers than stages)."""
    out = []
    for pipe in asg.pipelines:
        stages = pipe.stages[:run_layers]
        raw = [s.num_layers / full_layers * run_layers for s in stages]
        ls = [max(1, int(round(r))) for r in raw]
        while sum(ls) > run_layers:
            i = max(range(len(ls)), key=lambda i: ls[i] - raw[i])
            if ls[i] > 1:
                ls[i] -= 1
            else:
                ls.pop(i)
                stages = stages[:i] + stages[i + 1:]
                raw.pop(i)
        while sum(ls) < run_layers:
            i = min(range(len(ls)), key=lambda i: ls[i] - raw[i])
            ls[i] += 1
        out.append(PipelinePlan(
            [StagePlan(list(s.device_ids), l) for s, l in zip(stages, ls)],
            cost=pipe.cost, bottleneck=pipe.bottleneck))
    return Assignment(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--cluster", default="case_study", choices=CLUSTERS)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--deadline", type=float, default=30.0)
    ap.add_argument("--out-len", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--search-iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="continuous",
                    choices=("continuous", "static"),
                    help="iteration-level slot batching vs the paper's "
                         "static whole-batch engine")
    ap.add_argument("--cache-layout", default="contiguous",
                    choices=("contiguous", "paged"),
                    help="per-slot max_len cache rows vs block-paged KV "
                         "with per-stage pools (docs/memory.md)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefix-caching", action="store_true",
                    help="alias block-aligned shared prompt prefixes "
                         "copy-on-write and prefill only cold suffixes "
                         "(paged layout only)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prefills longer than this many tokens into "
                         "chunks interleaved with decode iterations "
                         "(0 = one-shot; paged layout only)")
    ap.add_argument("--prefix-hit-rate", type=float, default=0.0,
                    help="expected fraction of prompt tokens served from "
                         "the prefix cache; the scheduler plans KV "
                         "capacity against the deduplicated demand")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="generate prompts with this many shared system-"
                         "prompt tokens (exercises the prefix cache)")
    ap.add_argument("--host-mem-gb", type=float, default=0.0,
                    help="pool-wide host-memory budget for the page tier "
                         "(GB): the scheduler splits it across replicas "
                         "by device KV-capacity deficit and prefix "
                         "eviction demotes pages there instead of "
                         "deleting them (paged + --prefix-caching)")
    ap.add_argument("--host-swap-gbps", type=float, default=0.0,
                    help="host<->device swap (and peer-fetch) bandwidth "
                         "in Gbit/s the scheduler prices tiered hits at "
                         "(0 = ideal free swap)")
    ap.add_argument("--host-swap-cost", type=float, default=0.0,
                    help="serving-clock cost of swapping one block "
                         "between tiers, as a fraction of one iteration "
                         "(virtual-clock replays only)")
    ap.add_argument("--cluster-prefix", action="store_true",
                    help="join every replica into a shared prefix "
                         "directory: prompts whose prefix lives only on "
                         "a peer fetch the pages over the KV link, and "
                         "the router scores admission by resident prefix "
                         "instead of pure least-loaded")
    ap.add_argument("--prefix-route-weight", type=float, default=0.25,
                    help="router weight of one resident prefix block "
                         "against queue depth (0 = pure least-loaded)")
    ap.add_argument("--route-seed", type=int, default=None,
                    help="seed the router's dispatch tiebreaks instead "
                         "of the deterministic lowest-replica-id order")
    ap.add_argument("--prefix-working-set", type=int, default=0,
                    help="hot shared-prefix working set in TOKENS: the "
                         "scheduler derives the ACHIEVABLE per-replica "
                         "hit rate from tiered residency instead of "
                         "trusting --prefix-hit-rate verbatim")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split prefill and decode across replicas: the "
                         "scheduler also searches the role split, prefill "
                         "replicas hand finished KV pages to decode "
                         "replicas over the modeled link (paged layout, "
                         ">= 2 replicas)")
    ap.add_argument("--kv-link-gbps", type=float, default=0.0,
                    help="flat bandwidth of the prefill->decode KV link in "
                         "Gbit/s (0 = per-pair costs from the cluster's "
                         "comm matrices)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: propose up to --spec-k "
                         "tokens per slot per iteration and commit the "
                         "verified prefix in one multi-token target step "
                         "(token-identical to greedy decode; paged layout "
                         "+ attention-only stacks)")
    ap.add_argument("--draft-model", default="",
                    help="draft architecture from configs/ for the "
                         "proposer (empty = weight-free n-gram / "
                         "prompt-lookup proposing)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per target step; the "
                         "scheduler's acceptance-aware search may deepen "
                         "or shallow this per replica")
    ap.add_argument("--spec-alpha", type=float, default=0.7,
                    help="expected per-token draft acceptance rate the "
                         "scheduler plans decode cost per COMMITTED "
                         "token with")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=("auto", "search", "fp32", "bf16", "int8",
                             "fp8"),
                    help="paged KV pool storage precision: 'auto' keeps "
                         "the model default, int8/fp8 quantize pages with "
                         "per-token-per-head scales (dequant fused into "
                         "the paged kernels), and 'search' lets the "
                         "scheduler pick PER REPLICA — memory-bound "
                         "replicas quantize (docs/serving.md)")
    ap.add_argument("--kv-guard-layers", type=int, default=0,
                    help="pin this many layers at EACH END of the stack "
                         "at model precision under a quantized --kv-dtype "
                         "(quality guard: first/last layers are the "
                         "usual outliers)")
    ap.add_argument("--kvsan", action="store_true",
                    help="serve under the KVSAN page-lifecycle sanitizer "
                         "(repro.analysis.kvsan): every block's "
                         "alloc/write/alias/spill/free is shadow-checked "
                         "and refcount leaks surface as "
                         "ServeStats.kvsan_leaks; token streams are "
                         "identical, iterations cost more host time. "
                         "Needs --cache-layout paged")
    ap.add_argument("--spec-draft-cost", type=float, default=0.0,
                    help="modeled cost of one draft step: the scheduler "
                         "treats it as absolute seconds (> 0 makes slow "
                         "replicas speculate deeper), and virtual-clock "
                         "replays charge it per proposed token as a "
                         "fraction of an iteration — so served latencies "
                         "include the draft overhead the plan assumed")
    args = ap.parse_args()

    if args.prefix_hit_rate and args.cache_layout != "paged":
        import warnings
        warnings.warn(
            "--prefix-hit-rate only affects capacity planning with "
            "--cache-layout paged (contiguous replicas are simulated "
            "unbounded); ignoring it", stacklevel=1)
        args.prefix_hit_rate = 0.0
    pool = CLUSTERS[args.cluster]()
    cfg_full = get_config(args.arch)
    # the scheduler must plan for the prompts the engine will actually
    # serve: --shared-prefix prepends that many system-prompt tokens
    task = cm.Task(batch=1, s_in=args.prompt_len + args.shared_prefix,
                   s_out=args.out_len)
    print(f"scheduling {args.arch} on {args.cluster} "
          f"({len(pool)} GPUs, ${pool.price_per_hour:.2f}/h)...")
    if args.disaggregate and args.cache_layout != "paged":
        import warnings
        warnings.warn(
            "--disaggregate needs --cache-layout paged (the KV handoff is "
            "a page transfer); serving colocated", stacklevel=1)
        args.disaggregate = False
    if args.spec_decode and args.cache_layout != "paged":
        import warnings
        warnings.warn(
            "--spec-decode needs --cache-layout paged (multi-token "
            "verification runs through the paged context path); serving "
            "without it", stacklevel=1)
        args.spec_decode = False
    if args.kv_dtype != "auto" and args.cache_layout != "paged":
        import warnings
        warnings.warn(
            "--kv-dtype needs --cache-layout paged (precision is a "
            "page-pool layout); serving at model precision", stacklevel=1)
        args.kv_dtype = "auto"
    if (args.host_mem_gb > 0 or args.cluster_prefix) \
            and not (args.cache_layout == "paged" and args.prefix_caching):
        import warnings
        warnings.warn(
            "--host-mem-gb/--cluster-prefix need --cache-layout paged "
            "with --prefix-caching (tiers and the directory hold prefix "
            "blocks); serving without them", stacklevel=1)
        args.host_mem_gb = 0.0
        args.cluster_prefix = False
    # "auto" = model default everywhere; "search" = per-replica scheduler
    # choice; anything else fixes one pool precision for planning + serving
    kv_dtype = None if args.kv_dtype in ("auto", "search") else args.kv_dtype
    res = schedule(pool, args.arch, task, deadline=args.deadline,
                   rate=args.rate, iters=args.search_iters, seed=args.seed,
                   kv_block_size=(args.block_size
                                  if args.cache_layout == "paged" else None),
                   prefix_hit_rate=args.prefix_hit_rate,
                   disaggregate=args.disaggregate,
                   kv_link_gbps=args.kv_link_gbps,
                   spec_decode=args.spec_decode,
                   spec_alpha=args.spec_alpha,
                   spec_draft_cost=args.spec_draft_cost,
                   max_spec_k=max(args.spec_k, 1),
                   kv_dtype=kv_dtype,
                   kv_dtype_search=(args.kv_dtype == "search"),
                   host_tier_bytes=args.host_mem_gb * 1e9,
                   host_swap_gbps=args.host_swap_gbps,
                   prefix_working_set=args.prefix_working_set,
                   cluster_prefix=args.cluster_prefix)
    print(f"  assignment: {res.assignment.describe()}")
    print(f"  estimated SLO attainment: {res.attainment*100:.1f}%")
    if args.disaggregate:
        print(f"  roles: {res.roles if res.roles is not None else 'colocated'}")
    if args.spec_decode:
        print(f"  spec-k per replica: {res.spec_ks}")
    if args.kv_dtype == "search":
        shown = [d or "auto" for d in (res.kv_dtypes or [])]
        print(f"  kv-dtype per replica: {shown}")
    if args.host_mem_gb > 0:
        print(f"  host-tier blocks per replica: {res.host_blocks}")

    cfg = cfg_full.reduced() if args.reduced else cfg_full
    asg = scale_assignment(res.assignment, cfg_full.num_layers,
                           cfg.num_layers) if args.reduced else res.assignment
    # quality guard: pin the first/last N layers of the SERVED stack
    guard = []
    if args.kv_guard_layers > 0:
        n = min(args.kv_guard_layers, cfg.num_layers // 2)
        guard = list(range(n)) + list(range(cfg.num_layers - n,
                                            cfg.num_layers))
    max_len = args.prompt_len + args.shared_prefix + 8 + args.out_len
    if args.cache_layout == "paged":
        max_len += (-max_len) % args.block_size    # whole blocks
    engine = InferenceEngine(cfg, asg, key=jax.random.PRNGKey(args.seed),
                             policy=args.policy, max_len=max_len,
                             cache_layout=args.cache_layout,
                             block_size=args.block_size,
                             prefix_caching=args.prefix_caching,
                             prefill_chunk=args.prefill_chunk,
                             # the scheduler's deficit-weighted host-tier
                             # split (None = no host tier)
                             host_blocks=(res.host_blocks
                                          if res.host_blocks is not None
                                          else 0),
                             host_swap_cost=args.host_swap_cost,
                             cluster_prefix=args.cluster_prefix,
                             prefix_route_weight=args.prefix_route_weight,
                             route_seed=args.route_seed,
                             # the role split is the SCHEDULER's verdict:
                             # roles=None means colocated serving won the
                             # search, so don't force a default split
                             disaggregate=(args.disaggregate
                                           and res.roles is not None),
                             roles=res.roles if args.disaggregate else None,
                             kv_link_gbps=args.kv_link_gbps,
                             cluster=(pool if args.disaggregate
                                      and args.kv_link_gbps <= 0 else None),
                             spec_decode=args.spec_decode,
                             spec_k=args.spec_k,
                             draft_model=(args.draft_model or None),
                             spec_draft_token_cost=args.spec_draft_cost,
                             # the scheduler's acceptance-aware per-replica
                             # depths (0 = plain decode on that replica)
                             spec_ks=(res.spec_ks if args.spec_decode
                                      else None),
                             kv_dtype=kv_dtype,
                             # per-replica precision: the scheduler's
                             # choices (None entry = model default)
                             kv_dtypes=(res.kv_dtypes
                                        if args.kv_dtype == "search"
                                        else None),
                             kv_guard_layers=guard,
                             kvsan=args.kvsan)
    if args.shared_prefix:
        reqs = shared_prefix_workload(
            rate=args.rate, duration=args.duration, vocab=cfg.vocab_size,
            shared_len=args.shared_prefix, unique_len=args.prompt_len,
            unique_jitter=4, out_len=args.out_len, seed=args.seed)
    else:
        reqs = synth_workload(rate=args.rate, duration=args.duration,
                              vocab=cfg.vocab_size,
                              prompt_len=args.prompt_len,
                              prompt_jitter=4, out_len=args.out_len,
                              seed=args.seed)
    print(f"serving {len(reqs)} requests...")
    stats = engine.serve(reqs, deadline=args.deadline)
    print("  " + stats.summary())


if __name__ == "__main__":
    main()
