"""Serving driver: schedule a heterogeneous pool, build the asymmetric
pipeline engine, and serve a Poisson workload end to end.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --reduced --cluster case_study --rate 2 --duration 5 --deadline 30

Every flag is a ``serving.config.ServingConfig`` field — the CLI schema,
feature gating and derived planning inputs all live there; this driver is
just the parse -> schedule -> build -> serve spine. The scheduler plans
for the FULL model on the chosen GPU pool (the paper's setting);
execution on this CPU container runs the --reduced variant of the same
architecture through the scheduled stage layout, preserving every
structural property (stage count, TP degrees, layer ratios).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.core.scheduler import schedule
from repro.serving.config import CLUSTERS, ServingConfig


def scale_assignment(asg: Assignment, full_layers: int,
                     run_layers: int) -> Assignment:
    """Project a full-model layer split onto the reduced layer count,
    keeping stage proportions (>=1 layer per stage; stages collapse if the
    reduced model has fewer layers than stages)."""
    out = []
    for pipe in asg.pipelines:
        stages = pipe.stages[:run_layers]
        raw = [s.num_layers / full_layers * run_layers for s in stages]
        ls = [max(1, int(round(r))) for r in raw]
        while sum(ls) > run_layers:
            i = max(range(len(ls)), key=lambda i: ls[i] - raw[i])
            if ls[i] > 1:
                ls[i] -= 1
            else:
                ls.pop(i)
                stages = stages[:i] + stages[i + 1:]
                raw.pop(i)
        while sum(ls) < run_layers:
            i = min(range(len(ls)), key=lambda i: ls[i] - raw[i])
            ls[i] += 1
        out.append(PipelinePlan(
            [StagePlan(list(s.device_ids), l) for s, l in zip(stages, ls)],
            cost=pipe.cost, bottleneck=pipe.bottleneck))
    return Assignment(out)


def main() -> None:
    sv = ServingConfig.parse().normalized()
    pool = sv.pool()
    cfg_full = get_config(sv.arch)
    print(f"scheduling {sv.arch} on {sv.cluster} "
          f"({len(pool)} GPUs, ${pool.price_per_hour:.2f}/h)...")
    res = schedule(pool, sv.arch, sv.task(), **sv.schedule_kwargs())
    plan = res.plan
    print(f"  assignment: {plan.assignment.describe()}")
    print(f"  estimated SLO attainment: {res.attainment*100:.1f}%")
    if sv.disaggregate:
        print(f"  roles: "
              f"{plan.roles if plan.roles is not None else 'colocated'}")
    if sv.spec_decode:
        print(f"  spec-k per replica: {plan.spec_ks}")
    if sv.kv_dtype == "search":
        shown = [d or "auto" for d in (plan.kv_dtypes or [])]
        print(f"  kv-dtype per replica: {shown}")
    if sv.host_mem_gb > 0:
        print(f"  host-tier blocks per replica: {plan.host_blocks}")

    from repro.serving.engine import InferenceEngine
    cfg = cfg_full.reduced() if sv.reduced else cfg_full
    asg = scale_assignment(plan.assignment, cfg_full.num_layers,
                           cfg.num_layers) if sv.reduced else None
    engine = InferenceEngine.from_config(cfg, plan, sv, assignment=asg,
                                         cluster=pool)
    reqs = sv.workload(cfg.vocab_size)

    # ---- observability (repro.obs) --------------------------------------
    tracer = metrics = None
    if sv.trace_out or sv.calibrate:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    if sv.metrics_out or sv.calibrate:
        from repro.obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()

    print(f"serving {len(reqs)} requests...")
    stats = engine.serve(reqs, deadline=sv.deadline, tracer=tracer,
                         metrics=metrics)
    print("  " + stats.summary())

    if tracer is not None and metrics is not None:
        from repro.obs.metrics import phase_histograms_from_trace
        phase_histograms_from_trace(tracer, metrics)
    if sv.trace_out:
        tracer.write(sv.trace_out)
        print(f"  trace: {sv.trace_out} ({len(tracer.events)} events)")
    if sv.metrics_out:
        metrics.to_jsonl(sv.metrics_out)
        print(f"  metrics: {sv.metrics_out}")
    if sv.calibrate:
        from repro.core import cost_model as cm
        from repro.obs.calibration import (CostCalibrator,
                                           predictions_from_phase_costs)
        from repro.obs.report import calibration_table
        cal = CostCalibrator()
        task = sv.task()
        profile = cm.ModelProfile.from_config(
            cfg_full, bytes_per_el=task.bytes_per_el)
        for i, pipe in enumerate(plan.assignment.pipelines):
            pc = cm.pipeline_phase_costs(
                pool, [list(s.device_ids) for s in pipe.stages],
                [s.num_layers for s in pipe.stages], profile, task)
            predictions_from_phase_costs(cal, i, pc, task.s_in)
        cal.observe_trace(tracer)
        for line in calibration_table(cal):
            print("  " + line)


if __name__ == "__main__":
    main()
