import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production mesh; print memory_analysis / cost_analysis; dump roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k [--multi-pod] [--json out.json]

The two env lines above MUST stay the first statements in this module:
jax locks the device count at first init (see the dry-run spec).
"""

import argparse
import json
import sys
import time


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True) -> dict:
    import jax
    from repro.configs.base import INPUT_SHAPES
    from repro.launch import mesh as mesh_lib
    from repro.launch import roofline, specs

    # offline compile benchmarking: lower/compile times ARE the wall-clock
    # measurement this tool reports, nothing here is on the serving clock
    t0 = time.monotonic()             # repro: noqa[clock-discipline]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    fn, structs, shs, jkw, cfg = specs.build_dryrun(arch, shape_name, mesh,
                                                    multi_pod)
    jitted = jax.jit(fn, in_shardings=shs, **jkw)
    lowered = jitted.lower(*structs)
    t_lower = time.monotonic() - t0   # repro: noqa[clock-discipline]
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower  # repro: noqa[clock-discipline]

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception as e:                                    # CPU backend gaps
        mem["error"] = str(e)

    shape = INPUT_SHAPES[shape_name]
    rl = roofline.extract(
        compiled, model_flops=roofline.model_flops_estimate(cfg, shape),
        chips=chips)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "roofline": rl.as_dict(),
        "swa_variant": bool(cfg.swa_window and
                            specs.get_config(arch).swa_window == 0),
    }
    if verbose:
        print(f"== {arch} x {shape_name} mesh={rec['mesh']} ==")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {mem}")
        r = rec["roofline"]
        print(f"   flops/chip={r['flops_per_chip']:.3e} "
              f"hbm/chip={r['hbm_bytes_per_chip']:.3e}")
        print(f"   terms: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s -> {r['dominant']}")
        print(f"   collectives: {r['collective_bytes_per_chip']}")
        uf = r["useful_flops_frac"]
        print(f"   MODEL_FLOPS/HLO_FLOPS = "
              f"{uf:.3f}" if uf else "   (no flops reported)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    try:
        rec = run_one(args.arch, args.shape, args.multi_pod)
    except ValueError as e:
        rec = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "skipped": str(e)}
        print(f"SKIP: {e}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
