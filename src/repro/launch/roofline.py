"""Roofline-term extraction from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x peak)         [197 TFLOP/s bf16]
  memory term     = HLO_bytes / (chips x HBM bw)       [819 GB/s]
  collective term = collective_bytes / (chips x link)  [~50 GB/s ICI]

cost_analysis() of the SPMD-partitioned module reports *per-partition*
FLOPs/bytes, so the terms divide by per-chip peaks directly. Collective
bytes are parsed from the partitioned HLO text: we sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per-partition shapes; an approximation of wire
bytes documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, incl. tuples: '(bf16[2,3], f32[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-partition result bytes per collective kind."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)", ls)
        if not m:
            continue
        opname = m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-start"):
                out[kind] += _shape_bytes(m.group(1))
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    hbm_bytes: float             # per chip
    coll_bytes: Dict[str, int]   # per chip, by kind
    model_flops: float           # 6 N D (analytic, global)
    chips: int
    xla_raw: Optional[dict] = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.flops <= 0:
            return None
        return self.model_flops / (self.flops * self.chips)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": dict(self.coll_bytes),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "chips": self.chips,
            "xla_raw": self.xla_raw,
        }


def extract(compiled, *, model_flops: float, chips: int) -> Roofline:
    """Primary numbers from the trip-count-aware analyzer
    (launch/hlo_analysis.py); XLA's cost_analysis (which counts while bodies
    once) is kept in xla_raw for reference."""
    from repro.launch import hlo_analysis

    xla_cost = {}
    try:
        xla_cost = compiled.cost_analysis() or {}
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
    except Exception:
        pass
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    c = hlo_analysis.analyze(text)
    rl = Roofline(flops=c.flops, hbm_bytes=c.bytes,
                  coll_bytes={k: int(v) for k, v in c.coll.items()},
                  model_flops=model_flops, chips=chips)
    rl.xla_raw = {"flops": float(xla_cost.get("flops", 0.0)),
                  "bytes accessed": float(xla_cost.get("bytes accessed", 0.0))}
    return rl


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·D for train (fwd+bwd), 2·N_active·D for inference."""
    n_active = cfg.active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one decode token
