"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 50 \
      [--reduced] [--batch 8] [--seq 128] [--ckpt-dir ckpts] [--log-every 10]

Full-size configs on the production mesh are exercised via dryrun.py; this
driver actually executes, so on CPU use --reduced or a small arch.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.models import model as M
from repro.training import optimizer
from repro.training.data import DataConfig, SyntheticStream
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params~{cfg.total_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    opt_cfg = optimizer.AdamWConfig(lr=args.lr, warmup_steps=20,
                                    total_steps=args.steps)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    data = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      batch_size=args.batch,
                                      seed=args.seed))
    start = 0
    if args.ckpt_dir and (s := ckpt.latest_step(args.ckpt_dir)) is not None:
        params, opt_state, meta = ckpt.restore(args.ckpt_dir, s, params,
                                               opt_state)
        start = meta["step"]
        print(f"resumed from step {start}")

    # training-throughput logging: real tokens/s over real elapsed time,
    # outside the serving path and its virtual clock entirely
    t0 = time.time()                  # repro: noqa[clock-discipline]
    losses = []
    for step in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}
        if cfg.num_image_tokens:
            batch["image_embeds"] = jax.numpy.zeros(
                (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
        if cfg.is_encoder_decoder:
            batch["enc_frames"] = jax.numpy.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model), cfg.dtype)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1 - start) * args.batch * args.seq \
                / (time.time() - t0)  # repro: noqa[clock-discipline]
            print(f"step {step+1:5d} loss {float(loss):.4f} "
                  f"({rate:.0f} tok/s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, params, opt_state)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params, opt_state)
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
