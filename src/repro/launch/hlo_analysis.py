"""Trip-count-aware cost analysis of compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body
exactly once (verified on the CPU backend), which under-counts scan-over-
layers models by the layer count. This module re-derives

  flops            (dot/convolution/elementwise, x trip counts)
  bytes accessed   (operand + result bytes of top-level instructions)
  collective bytes (all-gather/all-reduce/reduce-scatter/all-to-all/
                    collective-permute result bytes, x trip counts)

by parsing ``compiled.as_text()``: computations are parsed into instruction
lists; while ops multiply their body/condition cost by the
``known_trip_count`` backend config (1 if absent); fusions/calls recurse.
Shapes are per-partition (SPMD), so totals are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_instr_line(line: str):
    """Returns (name, type_str, op, rest) or None. Handles tuple types with
    embedded /*index=N*/ comments via balanced-paren scanning."""
    line = _COMMENT_RE.sub("", line)
    m = _LHS_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    s = line[m.end():]
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, s = s[:i + 1], s[i + 1:]
    else:
        mt = re.match(r"([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)", s)
        if not mt:
            return None
        type_str, s = mt.group(1), s[mt.end():]
    mo = re.match(r"\s+([\w\-]+)\((.*)$", s)
    if not mo:
        return None
    return name, type_str, mo.group(1), mo.group(2)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|computation)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_info(type_str: str) -> Tuple[int, List[int]]:
    """(total bytes, dims of first array) of an HLO type string."""
    total = 0
    first_dims: List[int] = []
    for i, (dt, dims) in enumerate(_SHAPE_RE.findall(type_str)):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if i == 0:
            first_dims = ds
    return total, first_dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    bytes_: int
    dims: List[int]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVES}

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in COLLECTIVES:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "negate", "abs", "compare", "select", "clamp", "floor",
    "ceil", "round-nearest-afz", "sign", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "remainder", "power",
}
_ELEMENTWISE_K = {"exponential": 4, "log": 4, "tanh": 4, "logistic": 4,
                  "rsqrt": 2, "sqrt": 2, "cosine": 4, "sine": 4,
                  "exponential-minus-one": 4, "log-plus-one": 4, "erf": 4,
                  "atan2": 4, "cbrt": 4}


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line)
            if hdr and ("{" in line):
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            got = _parse_instr_line(line)
            if got:
                name, tstr, op, rest = got
                b, dims = _shape_info(tstr)
                self.computations[cur].append(
                    Instr(name, tstr, op, rest, b, dims))

    # ------------------------------------------------------------------
    def _shapes_of(self, comp: str) -> Dict[str, Instr]:
        return {i.name: i for i in self.computations.get(comp, [])}

    def _dot_flops(self, ins: Instr, scope: Dict[str, Instr]) -> float:
        out_elems = 1
        for d in ins.dims:
            out_elems *= d
        # contraction size from lhs shape + lhs_contracting_dims
        ops = self._operands(ins)
        lhs = scope.get(ops[0]) if ops else None
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        k = 1
        if lhs is not None and mc:
            for di in mc.group(1).split(","):
                if di:
                    k *= lhs.dims[int(di)]
        return 2.0 * out_elems * k

    def _conv_flops(self, ins: Instr, scope: Dict[str, Instr]) -> float:
        out_elems = 1
        for d in ins.dims:
            out_elems *= d
        ops = self._operands(ins)
        ker = scope.get(ops[1]) if len(ops) > 1 else None
        k = 1
        if ker is not None:
            for d in ker.dims:
                k *= d
            # divide by output features (last dim of kernel, conventionally)
            if ker.dims:
                k //= max(ker.dims[-1], 1)
        return 2.0 * out_elems * max(k, 1)

    def _fusion_bytes(self, sub: str, boundary_operand_bytes) -> float:
        """HBM traffic of one fusion execution with per-operand utilization:
        a parameter consumed ONLY through (dynamic-)slice/gather reads just
        the sliced bytes; a dynamic-update-slice root writes just the update.
        """
        comp = self.computations.get(sub, [])
        if not comp:
            return 0.0
        by_name = {i.name: i for i in comp}
        consumers: Dict[str, List[Instr]] = {}
        for ins in comp:
            for o in self._operands(ins):
                if o in by_name:
                    consumers.setdefault(o, []).append(ins)
        read = 0.0
        for ins in comp:
            if ins.op != "parameter":
                continue
            cons = consumers.get(ins.name, [])
            if cons and all(c.op in ("dynamic-slice", "slice", "gather")
                            for c in cons):
                read += sum(c.bytes_ for c in cons)
            elif cons and all(
                    c.op == "dynamic-update-slice"
                    and self._operands(c)[:1] == [ins.name]
                    for c in cons):
                # in-place update target: XLA aliases it; no read traffic
                pass
            else:
                read += ins.bytes_
        root = comp[-1]
        if root.op == "dynamic-update-slice":
            ops = self._operands(root)
            upd = by_name.get(ops[1]) if len(ops) > 1 else None
            write = upd.bytes_ if upd is not None else root.bytes_
        else:
            write = root.bytes_
        return read + write

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total          # guards accidental cycles
        scope = self._shapes_of(comp)
        for ins in self.computations.get(comp, []):
            c = Cost()
            elems = 1
            for d in ins.dims:
                elems *= d
            opnd_bytes = [scope[o].bytes_ for o in self._operands(ins)
                          if o in scope]
            if ins.op == "dot":
                c.flops = self._dot_flops(ins, scope)
                c.bytes = ins.bytes_ + sum(opnd_bytes)
            elif ins.op == "convolution":
                c.flops = self._conv_flops(ins, scope)
                c.bytes = ins.bytes_ + sum(opnd_bytes)
            elif ins.op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                for sub in _CALLED_RE.findall(ins.rest):
                    c += self.comp_cost(sub).scaled(trips)
            elif ins.op == "conditional":
                mb = _BRANCHES_RE.search(ins.rest)
                if mb:
                    subs = [s.strip().lstrip("%")
                            for s in mb.group(1).split(",")]
                else:
                    subs = _CALLED_RE.findall(ins.rest)
                if subs:
                    branch_costs = [self.comp_cost(s) for s in subs]
                    c = max(branch_costs, key=lambda b: b.flops + b.bytes)
            elif ins.op in ("fusion", "custom-call"):
                for sub in _CALLED_RE.findall(ins.rest):
                    inner = self.comp_cost(sub)
                    # fused internals stay in registers: flops+collectives
                    # propagate, bytes come from boundary utilization
                    c.flops += inner.flops
                    for k in COLLECTIVES:
                        c.coll[k] += inner.coll[k]
                    c.bytes += self._fusion_bytes(sub, opnd_bytes)
            elif ins.op in ("call", "map", "reduce", "reduce-window", "sort",
                            "scatter", "select-and-scatter"):
                for sub in _CALLED_RE.findall(ins.rest):
                    inner = self.comp_cost(sub)
                    c.flops += inner.flops
                    for k in COLLECTIVES:
                        c.coll[k] += inner.coll[k]
                c.bytes += ins.bytes_ + sum(opnd_bytes)
                if ins.op != "call":
                    in_elems = max(
                        (b // 4 for b in opnd_bytes), default=elems)
                    c.flops += in_elems
            elif ins.op in COLLECTIVES or any(
                    ins.op == k + "-start" for k in COLLECTIVES):
                kind = ins.op.replace("-start", "")
                c.coll[kind] += ins.bytes_
                c.bytes += ins.bytes_
            elif ins.op in _ELEMENTWISE_1:
                c.flops = elems
                c.bytes = ins.bytes_ + sum(opnd_bytes)
            elif ins.op in _ELEMENTWISE_K:
                c.flops = elems * _ELEMENTWISE_K[ins.op]
                c.bytes = ins.bytes_ + sum(opnd_bytes)
            elif ins.op == "dynamic-update-slice":
                ops = self._operands(ins)
                upd = scope.get(ops[1]) if len(ops) > 1 else None
                ub = upd.bytes_ if upd is not None else ins.bytes_
                c.bytes = 2 * ub
            elif ins.op in ("broadcast", "reshape", "transpose", "copy",
                            "concatenate", "slice", "dynamic-slice",
                            "gather", "pad", "convert", "reverse",
                            "bitcast-convert", "reduce-precision", "rng",
                            "rng-bit-generator"):
                c.bytes = 2 * ins.bytes_
            # parameter/constant/tuple/get-tuple-element/iota/bitcast: free
            total += c
        self._memo[comp] = total
        return total

    @staticmethod
    def _operands(ins: Instr) -> List[str]:
        # The operand region runs to the close paren matching the op's open
        # paren. Depending on the XLA version, operands print bare
        # ("%name") or with inline types ("f32[4,64]{1,0} %name", possibly
        # tuple types with nested parens/commas) — take the last token of
        # each depth-0 comma segment.
        s = ins.rest
        depth = 1
        end = len(s)
        for i, ch in enumerate(s):
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out = []
        seg_start, seg_depth = 0, 0
        inner = s[:end] + ","
        for i, ch in enumerate(inner):
            if ch in "([{":
                seg_depth += 1
            elif ch in ")]}":
                seg_depth -= 1
            elif ch == "," and seg_depth == 0:
                part = inner[seg_start:i].strip()
                seg_start = i + 1
                if part:
                    out.append(part.split()[-1].lstrip("%"))
        return out

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def np_prod(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
