"""Deterministic synthetic token pipeline (no external datasets offline).

Generates a stationary Markov-chain token stream per document: next-token
structure a model can actually learn (loss decreases measurably within a few
hundred steps), unlike uniform noise. Batches are reproducible from (seed,
step) so the pipeline is stateless and restart-safe — checkpoint resume
replays the exact stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    markov_states: int = 64


class SyntheticStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k = min(cfg.markov_states, cfg.vocab_size)
        # sparse-ish row-stochastic transition over a k-token active set
        self.active = rng.choice(cfg.vocab_size, size=k, replace=False)
        raw = rng.random((k, k)) ** 4          # peaky rows
        self.trans = raw / raw.sum(1, keepdims=True)
        self.k = k

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        states = rng.integers(0, self.k, size=cfg.batch_size)
        toks = np.empty((cfg.batch_size, cfg.seq_len), np.int32)
        # vectorized chain sampling via inverse-CDF per step
        cdf = np.cumsum(self.trans, axis=1)
        for t in range(cfg.seq_len):
            toks[:, t] = self.active[states]
            u = rng.random(cfg.batch_size)
            states = (cdf[states] < u[:, None]).sum(1).clip(0, self.k - 1)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
