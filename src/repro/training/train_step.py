"""Training step: loss / grad / AdamW update, donation-friendly."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer


def make_train_step(cfg: ModelConfig, opt_cfg: optimizer.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        new_params, new_state = optimizer.apply(opt_cfg, grads, opt_state,
                                                params)
        return new_params, new_state, loss
    return train_step
