"""Pure-JAX AdamW with cosine schedule (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree.map(jnp.copy, z))


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def apply(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step_dir = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:                          # decay matrices only
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, mu, nu) for p, g, mu, nu
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)
