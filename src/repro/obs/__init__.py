"""HexTrace observability: span tracing, metrics, and cost calibration.

Three layers, each consumable alone (docs/observability.md):

  * ``repro.obs.trace``        — ``Tracer`` riding the serving clock;
    Chrome-trace/Perfetto JSON export; ``NULL_TRACER`` zero-overhead off
    switch.
  * ``repro.obs.metrics``      — ``MetricsRegistry`` of labeled
    counters/gauges/histograms with deterministic JSONL export;
    ``ServeStats`` publishes into it as a back-compat view.
  * ``repro.obs.calibration``  — predicted (cost_model/slo_sim) vs
    observed (span durations) per-(replica, phase) error report feeding
    ``core.resched.DriftDetector``'s model-error signal.

``python -m repro.obs.report`` summarizes/validates the exports.
"""
from repro.obs.calibration import (CostCalibrator,
                                   predictions_from_phase_costs)
from repro.obs.metrics import (MetricsRegistry,
                               phase_histograms_from_trace)
from repro.obs.trace import (NULL_TRACER, Span, Tracer,
                             validate_chrome_trace)

__all__ = [
    "CostCalibrator", "MetricsRegistry", "NULL_TRACER", "Span", "Tracer",
    "phase_histograms_from_trace", "predictions_from_phase_costs",
    "validate_chrome_trace",
]
