"""HexTrace: span-based request tracing over the serving clock.

The serving stack is instrumented with a single ``Tracer`` that rides
whatever clock the serve loop runs on (``WallClock`` or ``VirtualClock``)
and records three event shapes:

  * **complete events** — a named interval with an explicit duration.
    This is the workhorse: under ``VirtualClock`` the clock does NOT
    advance while a worker iteration runs (the loop ticks once per cycle
    by the slowest worker's cost), so engines report the virtual cost
    they attribute to each phase as the span duration instead of
    sampling the clock twice.
  * **begin/end spans** — a matched pair sampled from the clock, for
    intervals that straddle loop cycles (per-worker iteration spans).
    Every ``begin`` must be closed by ``end`` on the same code path —
    the repro-lint ``span-pairing`` rule enforces this statically.
  * **instant events** — zero-duration markers (preemption, replica
    kill, KVSAN audit).

Determinism contract: with tracing ON, serving must stay token-identical
to an untraced run (the tracer only reads state), and two seeded
``VirtualClock`` runs must produce byte-identical exports. Nothing in
this module consults wall time, object ids, or unordered iteration —
events serialize in append order with sorted keys.

Zero-overhead contract: ``NULL_TRACER`` is a singleton with
``enabled = False``; hot paths guard emission with
``if tracer.enabled:`` so tracing off costs one attribute load.

Export is the Chrome trace-event JSON format (the ``traceEvents`` array
of ``ph: "X"/"i"`` dicts) readable by Perfetto (https://ui.perfetto.dev)
and ``chrome://tracing``; ``pid`` is the replica id and ``tid`` the
stage/lane within it, so the timeline groups by replica.
"""
from __future__ import annotations

import contextlib
import json
from typing import Dict, List, Optional, Sequence

# one trace-time unit (clock seconds) = 1e6 Chrome microseconds
_US = 1_000_000

# span taxonomy (docs/observability.md mirrors this table)
SPAN_NAMES = (
    "queue_wait",        # admit: arrival -> start_time
    "iteration",         # per-worker engine iteration (begin/end pair)
    "prefill",           # prompt tokens computed this iteration (chunk)
    "decode",            # one decode step over the running batch
    "spec_propose",      # draft tokens proposed
    "spec_verify",       # multi-token verification step
    "spec_rollback",     # rejected-draft KV truncation
    "preempt",           # slot evicted (instant) + recompute accounted
    "host_spill",        # device -> host page demotion
    "host_promote",      # host -> device page swap-in
    "prefix_fetch",      # cluster prefix-directory block migration
    "kv_migration",      # disaggregated prefill -> decode KV handoff
    "live_move",         # online-resched live slot extraction
    "replica_kill",      # rescheduler killed a replica (instant)
)


class Span:
    """An open begin/end interval; closed by ``Tracer.end``."""

    __slots__ = ("name", "ts", "pid", "tid", "args")

    def __init__(self, name: str, ts: float, pid: int, tid: int,
                 args: Optional[dict]):
        self.name = name
        self.ts = ts
        self.pid = pid
        self.tid = tid
        self.args = args


class Tracer:
    """Collects trace events against a serving clock.

    Construct once per serve, ``bind_clock`` when the loop picks its
    clock (the Router does this), and hand the same instance to every
    engine. ``enabled`` is True; the NULL_TRACER stand-in is the off
    switch, so instrumentation sites never branch on a None check.
    """

    enabled = True

    def __init__(self, clock=None):
        self._clock = clock
        self.events: List[dict] = []
        # rid -> {"first_token": ts, "prefill_finish": ts}; the loop
        # re-derives Request timestamps from these marks after a traced
        # serve (the trace is the source of truth when tracing is on)
        self.request_marks: Dict[int, Dict[str, float]] = {}
        self._open = 0                 # begun-but-unended spans

    # -- clock ------------------------------------------------------------
    def bind_clock(self, clock) -> None:
        self._clock = clock

    def now(self) -> float:
        return self._clock.now() if self._clock is not None else 0.0

    # -- emission ---------------------------------------------------------
    def complete(self, name: str, dur: float, *, ts: Optional[float] = None,
                 pid: int = 0, tid: int = 0, **args) -> None:
        """Record a finished interval with an explicit duration."""
        ev = {"name": name, "ph": "X",
              "ts": self.now() if ts is None else ts,
              "dur": dur, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, *, ts: Optional[float] = None,
                pid: int = 0, tid: int = 0, **args) -> None:
        ev = {"name": name, "ph": "i",
              "ts": self.now() if ts is None else ts,
              "pid": pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def begin(self, name: str, *, pid: int = 0, tid: int = 0,
              **args) -> Span:
        """Open a clock-sampled span; MUST be closed with ``end`` on the
        same code path (repro-lint: span-pairing)."""
        self._open += 1
        return Span(name, self.now(), pid, tid, args or None)

    def end(self, span: Span, **args) -> None:
        self._open -= 1
        merged = dict(span.args) if span.args else {}
        merged.update(args)
        ev = {"name": span.name, "ph": "X", "ts": span.ts,
              "dur": self.now() - span.ts, "pid": span.pid,
              "tid": span.tid}
        if merged:
            ev["args"] = merged
        self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, pid: int = 0, tid: int = 0, **args):
        s = self.begin(name, pid=pid, tid=tid, **args)
        try:
            yield s
        finally:
            self.end(s)

    # -- request timestamp marks (satellite: timestamp sprawl) ------------
    def mark(self, rid: int, key: str, ts: float) -> None:
        """Stamp a request-lifecycle mark (first occurrence wins, matching
        the engines' ``if t is None`` stamping discipline)."""
        m = self.request_marks.setdefault(rid, {})
        if key not in m:
            m[key] = ts

    def apply_marks(self, requests: Sequence) -> None:
        """Re-derive Request timestamps from the trace. With tracing on
        the span stream is the source of truth for ``first_token_time``
        and ``prefill_finish_time``; values must agree with what the
        engines stamped inline (tests assert equality)."""
        for r in requests:
            m = self.request_marks.get(r.rid)
            if not m:
                continue
            if "first_token" in m:
                r.first_token_time = m["first_token"]
            if "prefill_finish" in m:
                r.prefill_finish_time = m["prefill_finish"]

    # -- export -----------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (ts/dur in microseconds)."""
        out = []
        for ev in self.events:
            d = dict(ev)
            d["ts"] = round(d["ts"] * _US)
            if "dur" in d:
                d["dur"] = round(d["dur"] * _US)
            out.append(d)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace",
                              "openSpans": self._open}}

    def dumps(self) -> str:
        """Byte-deterministic serialization (sorted keys, fixed
        separators, append-ordered events)."""
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":"))

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())
            f.write("\n")


class _NullTracer(Tracer):
    """Tracing off: every emission is a no-op; ``enabled`` is False so
    hot paths can skip argument construction entirely."""

    enabled = False

    def __init__(self):
        super().__init__()

    def complete(self, name, dur, *, ts=None, pid=0, tid=0, **args):
        pass

    def instant(self, name, *, ts=None, pid=0, tid=0, **args):
        pass

    def begin(self, name, *, pid=0, tid=0, **args):
        return _NULL_SPAN

    def end(self, span, **args):
        pass

    def mark(self, rid, key, ts):
        pass

    def apply_marks(self, requests):
        pass


_NULL_SPAN = Span("", 0.0, 0, 0, None)
NULL_TRACER = _NullTracer()


# ---------------------------------------------------------------------------
# Chrome-trace schema validation (ci.sh trace smoke, tests)
# ---------------------------------------------------------------------------

def validate_chrome_trace(obj, *, require_spans: Sequence[str] = ()
                          ) -> List[str]:
    """Structural check of a Chrome trace-event JSON object. Returns a
    list of problems (empty = valid). ``require_spans`` additionally
    demands at least one event with each given name."""
    errs: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be an array"]
    names = set()
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                errs.append(f"{where}: missing '{k}'")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "M", "C"):
            errs.append(f"{where}: unknown phase {ph!r}")
        if ph == "X" and "dur" not in ev:
            errs.append(f"{where}: complete event missing 'dur'")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: bad ts {ts!r}")
        if "dur" in ev and (not isinstance(ev["dur"], (int, float))
                            or ev["dur"] < 0):
            errs.append(f"{where}: bad dur {ev['dur']!r}")
        if isinstance(ev.get("name"), str):
            names.add(ev["name"])
    open_spans = (obj.get("otherData") or {}).get("openSpans", 0)
    if open_spans:
        errs.append(f"{open_spans} span(s) begun but never ended")
    for want in require_spans:
        if want not in names:
            errs.append(f"no '{want}' span in trace")
    return errs
