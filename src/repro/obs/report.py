"""Observability report CLI: summarize a metrics JSONL export, validate a
Chrome-trace JSON, and print the calibration table.

  python -m repro.obs.report metrics.jsonl
  python -m repro.obs.report metrics.jsonl --trace trace.json \\
      --require-spans prefill,decode
  python -m repro.obs.report --trace trace.json

Exit status is nonzero when a given trace fails schema validation or
misses a required span — ``scripts/ci.sh`` uses exactly that as the
trace smoke's gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs.calibration import CostCalibrator
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import validate_chrome_trace


def summarize_metrics(reg: MetricsRegistry) -> List[str]:
    lines: List[str] = []
    counters = [r for r in reg.collect() if r["kind"] == "counter"
                and r["value"]]
    gauges = [r for r in reg.collect() if r["kind"] == "gauge"]
    hists = [r for r in reg.collect() if r["kind"] == "histogram"
             and r["count"]]

    def lbl(row):
        return ",".join(f"{k}={v}" for k, v in sorted(row["labels"].items()))
    if counters:
        lines.append("counters:")
        lines.extend(f"  {r['name']}{{{lbl(r)}}} = {r['value']}"
                     for r in counters)
    if gauges:
        lines.append("gauges:")
        lines.extend(f"  {r['name']}{{{lbl(r)}}} = {r['value']:g} "
                     f"(peak {r['peak']:g})" for r in gauges)
    if hists:
        lines.append("histograms:")
        for r in hists:
            mean = r["sum"] / r["count"]
            lines.append(f"  {r['name']}{{{lbl(r)}}} n={r['count']} "
                         f"mean={mean:.4g} min={r['min']:.4g} "
                         f"max={r['max']:.4g}")
    return lines


def summarize_trace(obj: dict) -> List[str]:
    by_name: dict = {}
    for ev in obj.get("traceEvents", []):
        name = ev.get("name", "?")
        n, dur = by_name.get(name, (0, 0))
        by_name[name] = (n + 1, dur + ev.get("dur", 0))
    lines = [f"trace: {sum(n for n, _ in by_name.values())} events"]
    for name in sorted(by_name):
        n, dur = by_name[name]
        lines.append(f"  {name}: {n} spans, {dur / 1e6:.3f}s total")
    return lines


def calibration_table(cal: CostCalibrator) -> List[str]:
    rows = cal.report()
    if not rows:
        return []
    lines = ["calibration (predicted vs observed seconds/unit):",
             f"  {'replica':>7} {'phase':<14} {'predicted':>10} "
             f"{'observed':>10} {'rel_err':>8} {'spans':>6}"]
    for r in rows:
        pred = f"{r['predicted']:.4g}" if r["predicted"] is not None \
            else "-"
        rel = f"{r['rel_err'] * 100:.1f}%" if r["rel_err"] is not None \
            else "-"
        lines.append(f"  {r['replica']:>7} {r['phase']:<14} {pred:>10} "
                     f"{r['observed']:>10.4g} {rel:>8} {r['spans']:>6}")
    lines.append("  " + cal.summary())
    return lines


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="summarize serving metrics / validate traces")
    ap.add_argument("metrics", nargs="?", default=None,
                    help="metrics JSONL from a serve (--metrics-out)")
    ap.add_argument("--trace", default=None,
                    help="Chrome-trace JSON to validate + summarize")
    ap.add_argument("--require-spans", default="",
                    help="comma-separated span names the trace must "
                         "contain (validation fails otherwise)")
    args = ap.parse_args(argv)
    if args.metrics is None and args.trace is None:
        ap.error("give a metrics JSONL and/or --trace")
    status = 0
    cal = CostCalibrator()
    if args.trace is not None:
        with open(args.trace) as f:
            obj = json.load(f)
        want = [s for s in args.require_spans.split(",") if s]
        errs = validate_chrome_trace(obj, require_spans=want)
        if errs:
            status = 1
            print(f"TRACE INVALID ({args.trace}):")
            for e in errs[:20]:
                print(f"  {e}")
        else:
            print(f"trace OK ({args.trace})")
        for line in summarize_trace(obj):
            print(line)
    if args.metrics is not None:
        reg = MetricsRegistry.from_jsonl(args.metrics)
        for line in summarize_metrics(reg):
            print(line)
        cal.observe_metrics(reg)
        for line in calibration_table(cal):
            print(line)
    return status


if __name__ == "__main__":
    sys.exit(main())
