"""Metrics registry: labeled counters, gauges, and explicit-bucket
histograms with deterministic JSONL export.

``MetricsRegistry`` is the typed store behind serving observability:
the serve loop publishes its counters and latency distributions here
(``ServeStats.publish``), engines publish per-phase durations, and the
calibration layer (``repro.obs.calibration``) reads phase histograms
back out. Instruments are keyed by ``(kind, name, sorted(labels))`` so
the same name with different label sets (replica, stage, phase, ...)
stays distinct, Prometheus-style, without any global state.

Everything is plain Python floats/ints — no numpy in the hot path —
and ``collect()`` orders rows by key so exports are byte-deterministic
under ``VirtualClock`` runs.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# default latency buckets (seconds) — powers-of-two-ish decade sweep
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 60.0)


def _labelkey(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, v=1) -> None:
        self.value += v


class Gauge:
    """Last-write-wins sample with a high-water mark."""

    __slots__ = ("value", "peak")

    def __init__(self):
        self.value = 0.0
        self.peak = 0.0

    def set(self, v) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v


class Histogram:
    """Explicit-bucket histogram (upper-bound edges, +Inf implicit)
    that also tracks sum/count/min/max so means survive export."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        i = 0
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                break
        else:
            self.counts[len(self.buckets)] += 1
        self.sum += v
        self.count += 1
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile (upper-bound estimate; exact
        percentiles need the raw samples, which ServeStats keeps)."""
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i < len(self.buckets):
                    return self.buckets[i]
                return self.max if self.max is not None else 0.0
        return self.max if self.max is not None else 0.0


class MetricsRegistry:
    """Instrument factory + store. ``counter/gauge/histogram`` create on
    first use and return the live instrument thereafter."""

    def __init__(self):
        self._store: Dict[Tuple[str, str, LabelKey], object] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, name, _labelkey(labels))
        inst = self._store.get(key)
        if inst is None:
            inst = self._store[key] = factory()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, *, buckets: Sequence[float] =
                  DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    # -- queries ----------------------------------------------------------
    def value(self, name: str, **labels) -> Optional[float]:
        """Counter value or gauge sample for an exact (name, labels) key;
        None if absent."""
        for kind in ("counter", "gauge"):
            inst = self._store.get((kind, name, _labelkey(labels)))
            if inst is not None:
                return inst.value
        return None

    def total(self, name: str) -> float:
        """Sum of a counter across ALL label sets."""
        return sum(inst.value for (kind, n, _), inst in self._store.items()
                   if kind == "counter" and n == name)

    def histograms(self, name: str) -> List[Tuple[dict, Histogram]]:
        """All (labels, histogram) pairs for a name, key-ordered."""
        out = []
        for key in sorted(self._store):
            kind, n, lk = key
            if kind == "histogram" and n == name:
                out.append((dict(lk), self._store[key]))
        return out

    # -- export -----------------------------------------------------------
    def collect(self) -> List[dict]:
        """One row per instrument, ordered by key (deterministic)."""
        rows = []
        for key in sorted(self._store):
            kind, name, lk = key
            inst = self._store[key]
            row = {"kind": kind, "name": name, "labels": dict(lk)}
            if kind == "counter":
                row["value"] = inst.value
            elif kind == "gauge":
                row["value"] = inst.value
                row["peak"] = inst.peak
            else:
                row.update(buckets=list(inst.buckets),
                           counts=list(inst.counts), sum=inst.sum,
                           count=inst.count, min=inst.min, max=inst.max)
            rows.append(row)
        return rows

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for row in self.collect():
                f.write(json.dumps(row, sort_keys=True,
                                   separators=(",", ":")) + "\n")

    @classmethod
    def from_jsonl(cls, path: str) -> "MetricsRegistry":
        reg = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                labels = row.get("labels", {})
                if row["kind"] == "counter":
                    reg.counter(row["name"], **labels).inc(row["value"])
                elif row["kind"] == "gauge":
                    g = reg.gauge(row["name"], **labels)
                    g.set(row.get("peak", row["value"]))
                    g.set(row["value"])
                else:
                    h = reg.histogram(row["name"],
                                      buckets=row["buckets"], **labels)
                    h.counts = list(row["counts"])
                    h.sum = row["sum"]
                    h.count = row["count"]
                    h.min = row["min"]
                    h.max = row["max"]
        return reg


def phase_histograms_from_trace(tracer, registry: MetricsRegistry,
                                *, phases: Iterable[str] = ()) -> None:
    """Bridge: fold a tracer's complete events into per-(replica, phase)
    ``phase_seconds`` histograms (and ``phase_units`` counters when a
    span carries a ``tokens`` arg), so the calibration layer and the
    report CLI consume the metrics stream rather than raw spans."""
    want = set(phases) if phases else None
    for ev in tracer.events:
        if ev.get("ph") != "X":
            continue
        name = ev["name"]
        if want is not None and name not in want:
            continue
        labels = {"replica": str(ev.get("pid", 0)), "phase": name}
        registry.histogram("phase_seconds", **labels).observe(ev["dur"])
        toks = (ev.get("args") or {}).get("tokens")
        if toks:
            registry.counter("phase_units", **labels).inc(toks)
