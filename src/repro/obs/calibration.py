"""Predicted-vs-observed cost calibration.

HexGen's scheduler stakes every placement on ``core.cost_model`` phase
costs, and ROADMAP's "validate the cost model against reality" needs a
measurement to validate AGAINST. ``CostCalibrator`` holds both sides:

  * **predictions** — per-(replica, phase) expected seconds per unit,
    registered by whoever planned the serve (``launch.serve`` derives
    them from ``cost_model.pipeline_phase_costs`` /
    ``predicted_phase_seconds``; benches may use
    ``slo_sim.PhasedReplicaModel`` figures directly).
  * **observations** — span durations from the trace (or the
    ``phase_seconds`` histograms the metrics bridge builds), normalized
    to the same unit.

Units per phase: ``prefill`` and ``spec_propose`` are per TOKEN (spans
carry a ``tokens`` arg), everything else is per SPAN (one decode
iteration, one block swap, one fetch, one handoff).

``report()`` yields one row per (replica, phase) with absolute and
relative error — the shape ``benchmarks/bench_calibration.py`` lands in
``results/calibration.jsonl`` — and ``feed()`` pushes the errors into a
``core.resched.DriftDetector`` as the model-error drift signal.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

# phases whose span durations amortize over a token count
PER_TOKEN_PHASES = ("prefill", "spec_propose")

# lifecycle phases the calibrator aggregates from a trace (per-worker
# "iteration" and admission "queue_wait" spans stay out: they overlap the
# inner phases and would double-count)
PHASES = ("prefill", "decode", "spec_propose", "spec_verify",
          "host_spill", "host_promote", "prefix_fetch", "kv_migration")


class CostCalibrator:
    """Accumulates per-(replica, phase) predictions and observations."""

    def __init__(self):
        self._pred: Dict[Tuple[int, str], float] = {}
        # (replica, phase) -> [seconds, units, spans]
        self._obs: Dict[Tuple[int, str], List[float]] = {}

    # -- predictions ------------------------------------------------------
    def predict(self, replica: int, phase: str, seconds: float) -> None:
        """Register the model's expected seconds per unit of `phase` on
        `replica` (token for per-token phases, span otherwise)."""
        self._pred[(int(replica), phase)] = float(seconds)

    # -- observations -----------------------------------------------------
    def observe(self, replica: int, phase: str, seconds: float,
                units: float = 1.0) -> None:
        acc = self._obs.setdefault((int(replica), phase), [0.0, 0.0, 0])
        acc[0] += float(seconds)
        acc[1] += float(units)
        acc[2] += 1

    def observe_trace(self, tracer) -> None:
        """Fold a tracer's complete events into observations."""
        for ev in tracer.events:
            if ev.get("ph") != "X" or ev["name"] not in PHASES:
                continue
            args = ev.get("args") or {}
            units = (args.get("tokens", 1)
                     if ev["name"] in PER_TOKEN_PHASES else 1)
            self.observe(ev.get("pid", 0), ev["name"], ev["dur"],
                         max(units, 1))

    def observe_metrics(self, registry) -> None:
        """Read observations back out of ``phase_seconds`` histograms /
        ``phase_units`` counters (the metrics-stream path: a report can
        calibrate from an exported metrics.jsonl alone)."""
        for labels, h in registry.histograms("phase_seconds"):
            phase = labels.get("phase", "")
            if phase not in PHASES or not h.count:
                continue
            rep = int(labels.get("replica", 0))
            units = h.count
            if phase in PER_TOKEN_PHASES:
                toks = registry.value("phase_units", **labels)
                if toks:
                    units = toks
            acc = self._obs.setdefault((rep, phase), [0.0, 0.0, 0])
            acc[0] += h.sum
            acc[1] += units
            acc[2] += h.count

    # -- the report -------------------------------------------------------
    def report(self) -> List[dict]:
        """One row per (replica, phase) that has observations, key-ordered:
        predicted and observed seconds per unit, span/unit counts, and
        absolute + relative error (None when no prediction exists)."""
        rows = []
        for (rep, phase) in sorted(self._obs):
            sec, units, spans = self._obs[(rep, phase)]
            observed = sec / units if units else 0.0
            pred = self._pred.get((rep, phase))
            row = {"replica": rep, "phase": phase,
                   "predicted": pred, "observed": observed,
                   "spans": spans, "units": units,
                   "abs_err": None, "rel_err": None}
            if pred is not None:
                row["abs_err"] = abs(observed - pred)
                row["rel_err"] = (abs(observed - pred) / pred
                                  if pred > 0 else None)
            rows.append(row)
        return rows

    def feed(self, detector) -> int:
        """Push every row with a prediction into a DriftDetector's
        model-error window; returns the rows fed."""
        n = 0
        for row in self.report():
            if row["predicted"] is None:
                continue
            detector.observe_model_error(row["phase"], row["predicted"],
                                         row["observed"])
            n += 1
        return n

    def summary(self) -> str:
        rows = [r for r in self.report() if r["rel_err"] is not None]
        if not rows:
            return "calibration: no predicted phases observed"
        worst = max(rows, key=lambda r: r["rel_err"])
        mean = sum(r["rel_err"] for r in rows) / len(rows)
        return (f"calibration: {len(rows)} (replica, phase) pairs, "
                f"mean rel err {mean * 100:.1f}%, worst "
                f"{worst['phase']}@r{worst['replica']} "
                f"{worst['rel_err'] * 100:.1f}%")


def predictions_from_phase_costs(cal: CostCalibrator, replica: int,
                                 pc, s_in: int) -> None:
    """Register a replica's predictions from a ``cost_model.PhaseCosts``:
    prefill normalizes to seconds/token over the planned prompt length,
    decode is seconds per iteration."""
    cal.predict(replica, "prefill", pc.prefill_latency / max(s_in, 1))
    cal.predict(replica, "decode", pc.decode_latency)
