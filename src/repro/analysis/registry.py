"""Machine-readable kernel/oracle registry.

Every Pallas kernel in the repo (a top-level ``*_pallas`` function in one
of ``KERNEL_MODULES``) must be registered here with:

  * ``oracle``          — the pure-JAX reference implementation in
    ``kernels/ref.py`` the kernel is validated against (the repo's
    correctness bar is bitwise/tolerance parity with these oracles);
  * ``interpret_check`` — where CI runs the kernel in Pallas interpret
    mode against that oracle: ``"smoke:<suite>"`` (a suite of
    ``scripts/smoke_serving.py``) or ``"pytest:<path>"`` (a test file
    that calls the kernel with ``interpret=True``).

Two enforcement points read this table, so an unregistered or unchecked
kernel cannot ship:

  * the ``kernel-oracle`` lint rule (``repro.analysis.lint``) flags any
    ``*_pallas`` definition missing from the registry, and
    ``check_registry`` findings when the registry itself is stale;
  * ``benchmarks/run.py --check`` runs ``check_registry`` alongside the
    results-schema guard.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

# repo-relative module paths the kernel scan covers
KERNEL_MODULES = (
    "src/repro/kernels/paged_attention.py",
    "src/repro/kernels/flash_attention.py",
    "src/repro/kernels/ssm_scan.py",
    "src/repro/kernels/decode_attention.py",
)
ORACLE_MODULE = "src/repro/kernels/ref.py"

# kernel name -> (oracle in ref.py, interpret-mode CI check)
KERNEL_ORACLES: Dict[str, Dict[str, str]] = {
    "paged_decode_attention_pallas": {
        "oracle": "paged_decode_attention_ref",
        "interpret_check": "smoke:kernels",
    },
    "paged_decode_attention_quant_pallas": {
        "oracle": "paged_decode_attention_quant_ref",
        "interpret_check": "smoke:quant",
    },
    "paged_context_attention_pallas": {
        "oracle": "paged_context_attention_ref",
        "interpret_check": "smoke:kernels",
    },
    "paged_context_attention_quant_pallas": {
        "oracle": "paged_context_attention_quant_ref",
        "interpret_check": "smoke:quant",
    },
    "paged_verify_attention_pallas": {
        "oracle": "paged_verify_attention_ref",
        "interpret_check": "smoke:kernels",
    },
    "paged_verify_attention_quant_pallas": {
        "oracle": "paged_verify_attention_quant_ref",
        "interpret_check": "smoke:quant",
    },
    "flash_attention_pallas": {
        "oracle": "attention_ref",
        "interpret_check": "pytest:tests/test_kernels.py",
    },
    "ssm_scan_pallas": {
        "oracle": "ssm_scan_ref",
        "interpret_check": "pytest:tests/test_kernels.py",
    },
    "decode_attention_pallas": {
        "oracle": "decode_attention_ref",
        "interpret_check": "pytest:tests/test_paged.py",
    },
}


def repo_root() -> str:
    """The checkout root (this file lives at src/repro/analysis/)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _top_level_defs(path: str) -> List[Tuple[str, int]]:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    return [(n.name, n.lineno) for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def pallas_kernels(root: Optional[str] = None
                   ) -> Dict[str, Tuple[str, int]]:
    """Scan ``KERNEL_MODULES`` for top-level ``*_pallas`` definitions;
    returns {kernel name: (repo-relative path, line)}."""
    root = root if root is not None else repo_root()
    found: Dict[str, Tuple[str, int]] = {}
    for rel in KERNEL_MODULES:
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            continue
        for name, line in _top_level_defs(path):
            if name.endswith("_pallas"):
                found[name] = (rel, line)
    return found


def check_registry(root: Optional[str] = None) -> List[str]:
    """Validate the registry against the tree. Returns human-readable
    problems (empty = sound): unregistered kernels, stale entries,
    missing oracles, dangling interpret checks."""
    root = root if root is not None else repo_root()
    problems: List[str] = []
    kernels = pallas_kernels(root)
    for name, (rel, line) in sorted(kernels.items()):
        if name not in KERNEL_ORACLES:
            problems.append(
                f"{rel}:{line} kernel '{name}' has no registered oracle "
                "(add it to repro.analysis.registry.KERNEL_ORACLES)")
    oracle_path = os.path.join(root, ORACLE_MODULE)
    oracles = {n for n, _ in _top_level_defs(oracle_path)} \
        if os.path.exists(oracle_path) else set()
    for name, entry in sorted(KERNEL_ORACLES.items()):
        if name not in kernels:
            problems.append(
                f"registry entry '{name}' matches no *_pallas definition "
                f"in {', '.join(KERNEL_MODULES)} (stale registry?)")
        if entry["oracle"] not in oracles:
            problems.append(
                f"registry entry '{name}': oracle '{entry['oracle']}' "
                f"not found in {ORACLE_MODULE}")
        kind, _, target = entry["interpret_check"].partition(":")
        if kind == "smoke":
            smoke = os.path.join(root, "scripts", "smoke_serving.py")
            ok = os.path.exists(smoke)
            if ok:
                with open(smoke, encoding="utf-8") as f:
                    ok = re.search(rf"def suite_{re.escape(target)}\b",
                                   f.read()) is not None
            if not ok:
                problems.append(
                    f"registry entry '{name}': interpret check smoke "
                    f"suite '{target}' not defined in "
                    "scripts/smoke_serving.py")
        elif kind == "pytest":
            path = os.path.join(root, target)
            if not os.path.exists(path):
                problems.append(
                    f"registry entry '{name}': interpret check file "
                    f"{target} missing")
            else:
                with open(path, encoding="utf-8") as f:
                    src = f.read()
                if name not in src or "interpret" not in src:
                    problems.append(
                        f"registry entry '{name}': {target} never runs "
                        f"'{name}' in interpret mode")
        else:
            problems.append(
                f"registry entry '{name}': unknown interpret_check "
                f"kind '{kind}'")
    return problems
