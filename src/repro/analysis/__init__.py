"""Repo-specific static analysis + runtime sanitizers.

Seven PRs of serving features (paged KV, COW prefix caching, speculative
rollback, quantized pages, host-tier spill, cluster prefix fetch) all rest
on hand-maintained invariants: refcount conservation, one-tier-at-a-time
residency, virtual-clock determinism, kernel/oracle bitwise parity. This
package machine-checks them on every commit instead of rediscovering them
per PR:

  * ``repro.analysis.lint``     — AST-based static pass with repo-specific
    rules (``python -m repro.analysis.lint src/``); findings print as
    ``file:line rule-id message`` and ``# repro: noqa[rule-id]``
    suppresses a line. Rule catalog: docs/analysis.md.
  * ``repro.analysis.registry`` — the machine-readable kernel/oracle
    registry the ``kernel-oracle`` rule and ``benchmarks/run.py --check``
    both enforce: every ``*_pallas`` kernel must name its pure-JAX oracle
    and an interpret-mode CI check.
  * ``repro.analysis.kvsan``    — KVSAN, an opt-in runtime sanitizer
    (``PagedPipelineBatcher(kvsan=True)`` / ``launch.serve --kvsan``)
    shadowing every KV page's lifecycle (alloc -> write -> COW-alias ->
    spill -> promote -> migrate -> free) in a pure-Python model; serving
    under KVSAN is token-identical, leaks surface as
    ``ServeStats.kvsan_leaks``.
"""
