"""repro-lint: AST-based static analysis with repo-specific rules.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint [PATH ...]   # default: src

Findings print one per line as ``file:line rule-id message``; exit status
is 1 when any finding survives, 0 on a clean tree.  A finding is
suppressed by a ``# repro: noqa[rule-id]`` comment on the same line
(comma-separate several ids; bare ``# repro: noqa`` silences every rule)
— use it only for *intentional* violations and justify it in an adjacent
comment.  Rule catalog with rationale: docs/analysis.md.

Rules
-----
``clock-discipline``   wall-clock calls (``time.time``/``monotonic``/
                       ``sleep``/``datetime.now`` ...) anywhere except
                       ``serving/loop.py``, which owns the Wall/Virtual
                       clock seam.  Guards virtual-clock determinism.
``jit-retrace``        ``jax.jit``/``jax.pmap`` calls outside setup
                       methods, or device-array construction with a
                       ``len(...)``-derived shape, in serving-path files.
                       Guards the fixed compile-shape bucketing
                       discipline (steady-state decode must not retrace).
``kernel-oracle``      a ``*_pallas`` kernel not present in
                       ``repro.analysis.registry.KERNEL_ORACLES`` (and,
                       when kernel modules are in the linted set, any
                       registry staleness from ``check_registry``).
``refcount-pairing``   a class acquires pool references (``.alloc``/
                       ``.incref``) but has no ``free``/``release``/
                       ``truncate``/``decref`` path at all.
``bare-except``        ``except:`` with no exception type.
``mutable-default``    mutable default argument (``[]``/``{}``/``set()``).
``unseeded-rng``       global-state RNG draws (``random.*``,
                       ``np.random.*``) instead of an explicitly seeded
                       ``default_rng``/``RandomState``/``PRNGKey``.
``span-pairing``       a ``tracer.begin(...)`` in a function with no
                       ``tracer.end(...)`` anywhere in the same function.
                       An unclosed span corrupts the Chrome-trace export
                       (``openSpans`` validation fails); prefer the
                       ``with tracer.span(...)`` context manager.
"""
from __future__ import annotations

import ast
import os
import re
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis import registry as _registry

RULES: Dict[str, str] = {
    "clock-discipline": "wall-clock use outside serving/loop.py",
    "jit-retrace": "jit/retrace hazard on a per-iteration serving path",
    "kernel-oracle": "*_pallas kernel missing from the oracle registry",
    "refcount-pairing": "pool references acquired with no release path",
    "bare-except": "bare except: swallows every exception",
    "mutable-default": "mutable default argument",
    "unseeded-rng": "unseeded global-state RNG",
    "span-pairing": "tracer.begin() with no tracer.end() in the function",
}

# one-time-setup functions where jax.jit construction is the sanctioned
# pattern (compile once in __init__, reuse per iteration)
_SETUP_FUNCS = {"__init__", "__post_init__", "build", "setup"}

_TIME_ATTRS = {"time", "time_ns", "monotonic", "monotonic_ns",
               "perf_counter", "perf_counter_ns", "process_time", "sleep"}
_DATETIME_ATTRS = {"now", "utcnow", "today"}

_RANDOM_FUNCS = {"random", "randint", "randrange", "choice", "choices",
                 "shuffle", "sample", "uniform", "gauss", "betavariate",
                 "expovariate", "normalvariate", "getrandbits"}
# np.random.<attr> calls that are fine: constructing an explicitly seeded
# generator object (the repo-wide pattern)
_NP_RANDOM_OK = {"RandomState", "default_rng", "Generator", "SeedSequence",
                 "PCG64", "Philox"}

_ACQUIRE_ATTRS = {"alloc", "incref"}
_RELEASE_ATTRS = {"free", "release", "truncate", "decref"}

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_,\s-]*)\])?")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


def _noqa_map(source: str) -> Dict[int, Optional[Set[str]]]:
    """line -> suppressed rule ids (None = all rules)."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _NOQA_RE.search(text)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _is_serving_path(rel: str) -> bool:
    parts = rel.replace(os.sep, "/").split("/")
    stem = os.path.splitext(parts[-1])[0]
    return any(p == "serving" for p in parts[:-1]) or "serving" in stem


def _is_clock_exempt(rel: str) -> bool:
    return rel.replace(os.sep, "/").endswith("serving/loop.py")


def _contains_len_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "len"):
            return True
    return False


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str) -> None:
        self.rel = rel
        self.serving = _is_serving_path(rel)
        self.clock_exempt = _is_clock_exempt(rel)
        self.findings: List[Finding] = []
        self._func_stack: List[str] = []
        self._class_stack: List[ast.ClassDef] = []
        # per-class acquire sites, resolved when the class closes
        self._acquires: Dict[int, List[ast.Call]] = {}
        self._releases: Dict[int, bool] = {}
        # per-function tracer.begin sites / tracer.end presence, resolved
        # when the function closes (span-pairing)
        self._span_begins: List[List[ast.Call]] = []
        self._span_ends: List[bool] = []

    def _add(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(
            Finding(self.rel, getattr(node, "lineno", 1), rule, message))

    # -- defs --------------------------------------------------------

    def _visit_func(self, node) -> None:
        self._span_begins.append([])
        self._span_ends.append(False)
        if node.name.endswith("_pallas") and not self._func_stack \
                and not self._class_stack:
            if node.name not in _registry.KERNEL_ORACLES:
                self._add(node, "kernel-oracle",
                          f"kernel '{node.name}' has no entry in "
                          "repro.analysis.registry.KERNEL_ORACLES "
                          "(register its ref.py oracle and an "
                          "interpret-mode CI check)")
        for d in node.args.defaults + node.args.kw_defaults:
            if d is None:
                continue
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in ("list", "dict", "set") and not d.args
                and not d.keywords)
            if mutable:
                self._add(d, "mutable-default",
                          f"mutable default argument in '{node.name}' "
                          "is shared across calls; default to None")
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()
        begins = self._span_begins.pop()
        ended = self._span_ends.pop()
        if begins and not ended:
            for call in begins:
                self._add(call, "span-pairing",
                          f"tracer.begin() in '{node.name}' has no "
                          "matching tracer.end(); an unclosed span "
                          "corrupts the trace export — prefer "
                          "'with tracer.span(...)'")

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node)
        self._acquires[id(node)] = []
        self._releases[id(node)] = False
        self.generic_visit(node)
        self._class_stack.pop()
        if self._acquires[id(node)] and not self._releases[id(node)]:
            for call in self._acquires[id(node)]:
                attr = call.func.attr  # type: ignore[union-attr]
                self._add(call, "refcount-pairing",
                          f"class '{node.name}' acquires pool references "
                          f"via .{attr}() but defines no free/release/"
                          "truncate/decref path")

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        if node.type is None:
            self._add(node, "bare-except",
                      "bare 'except:' hides KeyboardInterrupt and real "
                      "bugs; catch a concrete exception")
        self.generic_visit(node)

    # -- calls -------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute):
            self._check_attr_call(node, node.func)
        self.generic_visit(node)

    def _in_setup(self) -> bool:
        return any(f in _SETUP_FUNCS or f.startswith("_init")
                   for f in self._func_stack)

    def _check_attr_call(self, node: ast.Call,
                         func: ast.Attribute) -> None:
        dotted = _dotted(func)
        attr = func.attr

        # clock-discipline
        if not self.clock_exempt:
            if dotted in {f"time.{a}" for a in _TIME_ATTRS}:
                self._add(node, "clock-discipline",
                          f"'{dotted}()' outside serving/loop.py breaks "
                          "virtual-clock determinism; take a Clock")
            elif attr in _DATETIME_ATTRS and dotted is not None and (
                    dotted.startswith("datetime.")
                    or dotted.startswith("date.")):
                self._add(node, "clock-discipline",
                          f"'{dotted}()' outside serving/loop.py breaks "
                          "virtual-clock determinism; take a Clock")

        # jit-retrace (serving-path files only)
        if self.serving and self._func_stack and not self._in_setup():
            if dotted in ("jax.jit", "jax.pmap"):
                self._add(node, "jit-retrace",
                          f"'{dotted}' inside '{self._func_stack[-1]}' "
                          "re-traces per call; compile once in __init__ "
                          "and reuse")
            elif dotted is not None and dotted.startswith("jnp.") and \
                    attr in ("zeros", "ones", "empty", "full", "arange"):
                if any(_contains_len_call(a) for a in
                       list(node.args) + [k.value for k in node.keywords]):
                    self._add(node, "jit-retrace",
                              f"'jnp.{attr}' shape derived from 'len(...)'"
                              " defeats compile-shape bucketing; pad to a "
                              "fixed bucket")

        # span-pairing bookkeeping: begin/end on a receiver named
        # *tracer (self.tracer, tracer, w.tracer, ...)
        if self._span_begins:
            recv = _dotted(func.value)
            if recv is not None and \
                    recv.split(".")[-1].lower().endswith("tracer"):
                if attr == "begin":
                    self._span_begins[-1].append(node)
                elif attr == "end":
                    self._span_ends[-1] = True

        # refcount-pairing bookkeeping
        if self._class_stack:
            cid = id(self._class_stack[-1])
            if attr in _ACQUIRE_ATTRS:
                self._acquires[cid].append(node)
            if attr in _RELEASE_ATTRS:
                self._releases[cid] = True

        # unseeded-rng
        if dotted is not None:
            if dotted in {f"random.{f}" for f in _RANDOM_FUNCS}:
                self._add(node, "unseeded-rng",
                          f"'{dotted}()' draws from the global RNG; use "
                          "np.random.default_rng(seed) or "
                          "jax.random.PRNGKey")
            elif (dotted.startswith(("np.random.", "numpy.random."))
                  and attr not in _NP_RANDOM_OK):
                self._add(node, "unseeded-rng",
                          f"'{dotted}()' draws from numpy's global RNG; "
                          "construct np.random.default_rng(seed)")


def lint_source(source: str, rel: str) -> List[Finding]:
    """Lint one file's source; ``rel`` is the path used for reporting
    and rule scoping."""
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 1, "parse-error", str(e.msg))]
    v = _Visitor(rel)
    v.visit(tree)
    noqa = _noqa_map(source)
    out = []
    for f in v.findings:
        rules = noqa.get(f.line, ())
        if rules is None or (rules and f.rule in rules):
            continue
        out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), rel or path)


def _iter_py(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(dirpath, fn)
                           for fn in sorted(filenames)
                           if fn.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_paths(paths: Sequence[str],
               registry_check: bool = True) -> List[Finding]:
    """Lint every .py under ``paths``.  When the linted set includes a
    kernel module, also cross-check the oracle registry itself."""
    findings: List[Finding] = []
    files = _iter_py(paths)
    for path in files:
        findings.extend(lint_file(path))
    if registry_check:
        kernel_basenames = {os.path.basename(m)
                            for m in _registry.KERNEL_MODULES}
        if any(os.path.basename(p) in kernel_basenames for p in files):
            for problem in _registry.check_registry():
                m = re.match(r"(\S+?):(\d+)\s+(.*)", problem)
                if m:
                    findings.append(Finding(m.group(1), int(m.group(2)),
                                            "kernel-oracle", m.group(3)))
                else:
                    findings.append(Finding(
                        "src/repro/analysis/registry.py", 1,
                        "kernel-oracle", problem))
    return sorted(set(findings), key=lambda f: (f.path, f.line, f.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific static analysis (rule catalog: "
                    "docs/analysis.md)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-registry-check", action="store_true",
                    help="skip the kernel/oracle registry cross-check")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule:18s} {desc}")
        return 0
    findings = lint_paths(args.paths,
                          registry_check=not args.no_registry_check)
    for f in findings:
        print(f)
    n_files = len(_iter_py(args.paths))
    if findings:
        print(f"repro-lint: {len(findings)} finding(s) in "
              f"{n_files} file(s)", file=sys.stderr)
        return 1
    print(f"repro-lint: {n_files} file(s) clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
