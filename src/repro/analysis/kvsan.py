"""KVSAN: an opt-in runtime sanitizer for the paged-KV lifecycle.

Every KV page in the serving stack moves through a small state machine::

      alloc            write             free
    FREE ----> ALLOC --------> WRITTEN --------> FREE
                 |    (prefill/decode/   ^  (release/truncate/
                 |     COW-copy/scatter) |   evict, ref -> 0)
                 +--- incref/free move the refcount without
                      changing the page state

and, for prefix pages, across tiers: device-resident (PrefixIndex) ->
host-resident (HostPagePool, spill) -> device again (promote) or gone
(LRU drop), with exactly ONE tier holding the payload at any instant.

``KVSanitizer`` shadows all of it in pure Python: it wraps a
``BlockPool``'s ``alloc``/``incref``/``free`` (sanitizer checks run
BEFORE the pool's own asserts, so a double free raises ``KVSanViolation``
with the stage and block id instead of a bare assert), tracks per-block
write state from the engine's kernel-dispatch hooks, mirrors each
``HostPagePool``'s resident-hash set, and audits refcount conservation
every serve iteration (every reference must be explained by a slot's
BlockTable, a PrefixIndex entry, or the pinned null block).

Violation classes:

  * double free / incref of a dead block / realloc of a live block
  * use-after-free: a kernel dispatch touches a freed block
  * read-before-write: a kernel reads a page no write ever landed in
  * two-tier aliasing: a hash demoted while already host-resident, or a
    host shadow diverging from the pool's actual contents
  * scale/payload disagreement: a quantized engine spilling pages
    without their scale leaves (or an unquantized one with them)
  * refcount leak: a pool reference no live table or index explains
    (counted, surfaced as ``ServeStats.kvsan_leaks``; conversely a
    DANGLING table reference raises immediately)

The sanitizer only observes — wrapped methods return exactly what the
originals return — so serving under ``kvsan=True`` is token-identical
to sanitizer-off runs (asserted by tests/test_analysis.py).

Wire-up: ``PagedPipelineBatcher(kvsan=True)``, ``launch.serve --kvsan``,
``scripts/smoke_serving.py --kvsan``. Hand-driven use for tests::

    san = KVSanitizer()
    san.attach_pool(0, pool)
    blocks = pool.alloc(2)
    san.note_write(0, blocks)
    san.slot_access(0, blocks, kv_len=20, write_start=16, block_size=16)
    pool.free(blocks[0]); pool.free(blocks[0])   # -> KVSanViolation
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Set

from repro.serving.block_manager import NULL_BLOCK, blocks_for_tokens

FREE = "free"
ALLOC = "alloc"          # allocated, no write landed yet
WRITTEN = "written"


class KVSanViolation(AssertionError):
    """A KV-page lifecycle invariant was broken (see module docstring)."""


class KVSanitizer:
    """Shadow model of every attached pool's page lifecycle.

    ``quant=True`` additionally demands scale leaves on every spilled
    page payload (the PR-6 twin-pool invariant: scales ride with their
    payload through every tier move).
    """

    def __init__(self, *, quant: bool = False):
        self.quant = quant
        self.violations: List[str] = []   # every violation ever raised
        self.leaks = 0                    # distinct leaked (stage, block)s
        self._state: Dict[int, Dict[int, str]] = {}
        self._ref: Dict[int, Dict[int, int]] = {}     # shadow refcounts
        self._host: Dict[int, Set[int]] = {}          # shadow host hashes
        self._leaked: Dict[int, Set[int]] = {}        # already-counted

    def violate(self, msg: str) -> None:
        self.violations.append(msg)
        raise KVSanViolation(msg)

    # ---- pool wrapping ---------------------------------------------------
    def attach_pool(self, si: int, pool) -> None:
        """Shadow ``pool`` (stage ``si``): wrap alloc/incref/free with
        sanitizer checks that run BEFORE the pool's own asserts."""
        st = self._state.setdefault(si, {})
        rf = self._ref.setdefault(si, {NULL_BLOCK: 1})
        for bid in range(1, pool.n_blocks):   # adopt pre-existing state
            r = pool.ref(bid)
            if r > 0:
                rf[bid] = r
                st[bid] = WRITTEN
        orig_alloc, orig_incref, orig_free = \
            pool.alloc, pool.incref, pool.free

        def alloc(n: int = 1):
            out = orig_alloc(n)
            if out is not None:
                for b in out:
                    if rf.get(b, 0) != 0:
                        self.violate(f"kvsan stage {si}: block {b} handed "
                                     "out while still referenced")
                    rf[b] = 1
                    st[b] = ALLOC
            return out

        def incref(bid: int):
            if rf.get(bid, 0) <= 0:
                self.violate(f"kvsan stage {si}: incref of dead block "
                             f"{bid} (use-after-free alias)")
            orig_incref(bid)
            rf[bid] += 1

        def free(bid: int):
            if bid != NULL_BLOCK:
                if rf.get(bid, 0) <= 0:
                    self.violate(f"kvsan stage {si}: double free of "
                                 f"block {bid}")
                rf[bid] -= 1
                if rf[bid] == 0:
                    st[bid] = FREE
            return orig_free(bid)

        pool.alloc, pool.incref, pool.free = alloc, incref, free

    # ---- write/read tracking (engine kernel-dispatch hooks) --------------
    def note_write(self, si: int, bids: Sequence[int]) -> None:
        """A page write landed in each of ``bids`` (scatter/copy paths)."""
        st = self._state.setdefault(si, {})
        for b in bids:
            if b == NULL_BLOCK:
                continue
            if st.get(b, FREE) == FREE:
                self.violate(f"kvsan stage {si}: write into freed block "
                             f"{b} (use-after-free write)")
            st[b] = WRITTEN

    def slot_access(self, si: int, blocks: Sequence[int], kv_len: int,
                    write_start: int, block_size: int) -> None:
        """One slot's kernel dispatch: writes tokens
        [write_start, kv_len), attends over [0, kv_len). Checks every
        touched block is live, every block read below ``write_start``
        was written, and marks the write range written.
        ``write_start == kv_len`` is a pure read (KV extraction)."""
        st = self._state.setdefault(si, {})
        nb = blocks_for_tokens(kv_len, block_size)
        if nb > len(blocks):
            self.violate(f"kvsan stage {si}: table holds {len(blocks)} "
                         f"blocks but kv_len {kv_len} needs {nb}")
        for bi in range(nb):
            bid = blocks[bi]
            if bid == NULL_BLOCK:
                self.violate(f"kvsan stage {si}: null block inside "
                             f"kv_len at block index {bi}")
            s = st.get(bid, FREE)
            if s == FREE:
                self.violate(f"kvsan stage {si}: kernel touches freed "
                             f"block {bid} (use-after-free)")
            if (bi + 1) * block_size <= write_start:
                if s != WRITTEN:
                    self.violate(f"kvsan stage {si}: kernel reads block "
                                 f"{bid} that no write ever landed in")
            else:
                if s == ALLOC and bi * block_size < write_start:
                    self.violate(f"kvsan stage {si}: kernel reads "
                                 f"unwritten tokens of block {bid}")
                if bi * block_size < kv_len and write_start < kv_len:
                    st[bid] = WRITTEN

    def on_copy(self, si: int, src: int, dst: int) -> None:
        """A COW page copy src -> dst (both must be live, src written)."""
        st = self._state.setdefault(si, {})
        if st.get(src, FREE) != WRITTEN:
            self.violate(f"kvsan stage {si}: COW copies from block {src} "
                         f"in state {st.get(src, FREE)!r}")
        if st.get(dst, FREE) == FREE:
            self.violate(f"kvsan stage {si}: COW copies into freed "
                         f"block {dst}")
        st[dst] = WRITTEN

    def on_spill(self, si: int, bid: int) -> None:
        """A prefix block's payload is about to demote device -> host."""
        st = self._state.setdefault(si, {})
        if st.get(bid, FREE) != WRITTEN:
            self.violate(f"kvsan stage {si}: spill extracts block {bid} "
                         f"in state {st.get(bid, FREE)!r}")

    # ---- host-tier wrapping ----------------------------------------------
    def attach_host(self, si: int, host) -> None:
        """Mirror ``host``'s resident-hash set and check tier/scale
        coherence on every demotion. Wrap AFTER the engine wires
        ``host.on_evict`` so the LRU-drop chain stays intact."""
        shadow = self._host.setdefault(si, set())
        shadow.update(getattr(host, "_pages", ()))
        orig_put, orig_get = host.put, host.get
        orig_discard, orig_ev = host.discard, host.on_evict

        def put(h: int, payload) -> None:
            if h in shadow:
                self.violate(f"kvsan stage {si}: hash {h} demoted while "
                             "already host-resident (two-tier alias)")
            self._check_payload(si, h, payload)
            shadow.add(h)
            orig_put(h, payload)

        def get(h: int):
            payload = orig_get(h)
            if payload is not None:
                shadow.discard(h)
            return payload

        def discard(h: int) -> None:
            shadow.discard(h)
            orig_discard(h)

        def on_evict(h: int) -> None:
            shadow.discard(h)
            if orig_ev is not None:
                orig_ev(h)

        # host.restore re-enters the wrapped put (instance attribute), so
        # it needs no wrapper of its own
        host.put, host.get, host.discard = put, get, discard
        host.on_evict = on_evict

    def _check_payload(self, si: int, h: int, payload) -> None:
        """Quantized pools must spill scales with their payload (and
        unquantized pools must not grow them): a page whose scales live
        in a different tier than its int8/fp8 payload dequantizes
        garbage on promotion."""
        if not isinstance(payload, (list, tuple)):
            return                 # opaque payload (hand-driven tests)
        kv_layers = [L for L in payload
                     if isinstance(L, dict) and "k" in L]
        if not kv_layers:
            return
        scaled = any("k_scale" in L or "v_scale" in L for L in kv_layers)
        if self.quant and not scaled:
            self.violate(f"kvsan stage {si}: quantized page {h} spilled "
                         "without scale leaves (scale/payload tier "
                         "disagreement)")
        if not self.quant and scaled:
            self.violate(f"kvsan stage {si}: unquantized page {h} "
                         "spilled with scale leaves (scale/payload "
                         "disagreement)")

    # ---- iteration-boundary audits ---------------------------------------
    def audit_pool(self, si: int, pool,
                   expected: Mapping[int, int]) -> int:
        """Refcount conservation for stage ``si``: ``expected`` maps block
        id -> references the engine can explain (slot tables + prefix
        index; the null block's pin is implied). Unexplained references
        are LEAKS (counted once per block, returned); a reference the
        engine expects but the pool lost is corruption and raises."""
        rf = self._ref.get(si, {})
        leaked = self._leaked.setdefault(si, set())
        fresh = 0
        for bid in range(pool.n_blocks):
            actual = pool.ref(bid)
            shadow = rf.get(bid, 1 if bid == NULL_BLOCK else 0)
            if actual != shadow:
                self.violate(f"kvsan stage {si}: shadow refcount for "
                             f"block {bid} diverged (shadow {shadow}, "
                             f"pool {actual})")
            exp = expected.get(bid, 0) + (1 if bid == NULL_BLOCK else 0)
            if actual > exp:
                if bid not in leaked:
                    leaked.add(bid)
                    fresh += 1
                    self.violations.append(
                        f"kvsan stage {si}: block {bid} holds "
                        f"{actual - exp} reference(s) no table or index "
                        "explains (leak)")
            else:
                leaked.discard(bid)
                if exp > actual:
                    self.violate(f"kvsan stage {si}: dangling "
                                 f"reference(s) to block {bid} "
                                 f"(expected {exp}, pool holds {actual})")
        self.leaks += fresh
        return fresh

    def audit_host(self, si: int, host) -> None:
        """The shadow hash set must equal the host pool's actual
        contents — a divergence means a payload moved tiers behind the
        wrapped methods' back."""
        actual = set(getattr(host, "_pages", ()))
        shadow = self._host.get(si, set())
        if actual != shadow:
            extra = sorted(actual - shadow)
            missing = sorted(shadow - actual)
            self.violate(f"kvsan stage {si}: host tier diverged from "
                         f"shadow (untracked={extra[:4]}, "
                         f"vanished={missing[:4]})")

    def state(self, si: int, bid: int) -> str:
        return self._state.get(si, {}).get(bid, FREE)
