"""The paper's analytical cost model (Table 1 / Appendix B), plus a
generalized form that derives per-layer parameter counts, FLOPs and cache
bytes from any ModelConfig (GQA, MoE active experts, SSM state) so the same
scheduler plans every assigned architecture.

paper_exact=True reproduces Table 1 literally:
  params/layer = 12 H^2            (w_K,Q,V,O: 4H^2; w_1,w_2: 8H^2)
  FLOPs/layer  = 24 b s H^2        (2 FLOPs per param per token)
  KV bytes     = 2 b s H B_type / layer
  activation buffers = 4 b s H B_type (reused across layers)
TP comm: 4 AllReduce phases per layer (2 AllReduce = ReduceScatter+AllGather
x 2 per layer under the BSP model); PP comm: fastest link between stages.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class Task:
    """One inference task t: batch, prompt length, output length."""
    batch: int                # b_t
    s_in: int
    s_out: int
    bytes_per_el: int = 2     # B_type (FP16/bf16)


# ---------------------------------------------------------------------------
# Quantized KV pages (models.quant.KV_DTYPES): effective cache bytes per
# element by pool storage precision. Quantized layouts add a float32
# per-token-per-head scale, amortized here over head_dim elements.
# ---------------------------------------------------------------------------

KV_DTYPE_PAYLOAD_BYTES = {"fp32": 4.0, "bf16": 2.0, "int8": 1.0, "fp8": 1.0}
_KV_QUANTIZED = ("int8", "fp8")
_KV_SCALE_HEAD_DIM = 128       # modeling default for the scale amortization


def kv_dtype_bytes_per_el(kv_dtype: Optional[str], *,
                          head_dim: int = _KV_SCALE_HEAD_DIM
                          ) -> Optional[float]:
    """Effective KV-cache bytes per element for a paged pool at `kv_dtype`,
    scale overhead included (4 / head_dim per element for int8/fp8).
    None (model-default precision) returns None: callers keep the
    bytes_per_el the profile was built with."""
    if kv_dtype is None:
        return None
    b = KV_DTYPE_PAYLOAD_BYTES[kv_dtype]
    if kv_dtype in _KV_QUANTIZED:
        b += 4.0 / head_dim
    return b


def _kv_width_factor(task: Task, kv_dtype: Optional[str]) -> float:
    """Multiplier rescaling a profile's kv_bytes_per_token_per_layer (baked
    at task.bytes_per_el) to the actual pool storage precision."""
    eff = kv_dtype_bytes_per_el(kv_dtype)
    if eff is None:
        return 1.0
    return eff / task.bytes_per_el


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """What the cost model needs to know about the served model."""
    name: str
    num_layers: int
    d_model: int
    params_per_layer: float        # weights scanned per generated token
    flops_per_layer_per_token: float   # 2 * active params
    kv_bytes_per_token_per_layer: float
    embed_params: float = 0.0
    paper_exact: bool = False

    @staticmethod
    def from_config(cfg: ModelConfig, paper_exact: bool = False,
                    bytes_per_el: int = 2) -> "ModelProfile":
        H = cfg.d_model
        if paper_exact:
            return ModelProfile(
                name=cfg.name, num_layers=cfg.num_layers, d_model=H,
                params_per_layer=12 * H * H,
                flops_per_layer_per_token=24 * H * H,
                kv_bytes_per_token_per_layer=2 * H * bytes_per_el,
                paper_exact=True)
        total_p = sum(cfg.params_per_layer(i) for i in range(cfg.num_layers))
        active_p = sum(cfg.active_params_per_layer(i)
                       for i in range(cfg.num_layers))
        kv = sum(cfg.kv_cache_bytes_per_token_layer(i, bytes_per_el)
                 for i in range(cfg.num_layers))
        L = cfg.num_layers
        return ModelProfile(
            name=cfg.name, num_layers=L, d_model=H,
            params_per_layer=total_p / L,
            flops_per_layer_per_token=2 * active_p / L,
            kv_bytes_per_token_per_layer=kv / L,
            embed_params=cfg.vocab_size * H * (1 if cfg.tie_embeddings else 2))


# ---------------------------------------------------------------------------
# Table 1 terms. `devices` are global device ids of one stage's TP group.
# ---------------------------------------------------------------------------

def comp_cost(cluster: Cluster, devices: Sequence[int], layers: int,
              model: ModelProfile, task: Task) -> float:
    """C_comp^{i,j}: memory-scan term + matmul term."""
    n = len(devices)
    B = task.bytes_per_el
    scan = max(model.params_per_layer * B * task.s_out
               / (n * cluster.devices[d].spec.mem_bw) for d in devices)
    flops = max(model.flops_per_layer_per_token * task.batch
                * (task.s_in + task.s_out) / (n * cluster.devices[d].spec.flops)
                for d in devices)
    return (scan + flops) * layers


def _tp_superstep(cluster: Cluster, devices: Sequence[int],
                  msg_bytes: float) -> float:
    n = len(devices)
    best = 0.0
    for d in devices:
        tot = 0.0
        for d2 in devices:
            if d2 == d:
                continue
            tot += cluster.lat[d, d2] + msg_bytes / (n * cluster.bw[d, d2])
        best = max(best, tot)
    return best


def comm_tp_phase(cluster: Cluster, devices: Sequence[int], layers: int,
                  model: ModelProfile, task: Task, phase: str) -> float:
    """One phase's share of the BSP AllReduce traffic: the prompt-wide
    supersteps belong to prefill, the per-generated-token ones to decode."""
    assert phase in ("prefill", "decode"), phase
    if len(devices) == 1:
        return 0.0
    B = task.bytes_per_el
    H = model.d_model
    if phase == "prefill":
        return _tp_superstep(cluster, devices,
                             task.batch * task.s_in * H * B) * 4 * layers
    return _tp_superstep(cluster, devices,
                         task.batch * H * B) * 4 * task.s_out * layers


def comm_tp_cost(cluster: Cluster, devices: Sequence[int], layers: int,
                 model: ModelProfile, task: Task) -> float:
    """C_comm-tp^{i,j}: BSP AllReduce pair per layer (4 supersteps)."""
    return comm_tp_phase(cluster, devices, layers, model, task, "prefill") \
        + comm_tp_phase(cluster, devices, layers, model, task, "decode")


def comm_pp_cost(cluster: Cluster, stage: Sequence[int],
                 next_stage: Sequence[int], task: Task,
                 model: ModelProfile) -> float:
    """C_comm-pp^{i,j}: fastest link between consecutive stages."""
    B = task.bytes_per_el
    H = model.d_model

    def best(msg_bytes: float) -> float:
        return min(cluster.lat[d, d2] + msg_bytes / cluster.bw[d, d2]
                   for d in stage for d2 in next_stage)

    return best(task.batch * task.s_in * H * B) \
        + best(task.batch * H * B) * task.s_out


def _kv_tokens_per_seq(task: Task, block_size: int = 0,
                       prefix_hit_rate: float = 0.0) -> int:
    """Cache tokens one sequence occupies. block_size == 0 is the contiguous
    layout (a full s_in + s_out row is reserved up front); block_size > 0 is
    the paged layout, which rounds ACTUAL usage up to whole blocks — the
    only over-reservation left is the partial tail block.

    prefix_hit_rate (paged only) is the expected fraction of prompt tokens
    served from the prefix cache: shared blocks are resident ONCE however
    many sequences alias them, so each additional sequence demands only its
    cold suffix + outputs. Sharing is block-granular, so the deduplicated
    span rounds DOWN to whole blocks (a partial chunk is never aliased)."""
    s_in = task.s_in
    if block_size and prefix_hit_rate > 0.0:
        shared = int(s_in * min(prefix_hit_rate, 1.0))
        s_in -= (shared // block_size) * block_size
    s_total = s_in + task.s_out
    if block_size:
        return -(-s_total // block_size) * block_size
    return s_total


def mem_bytes_per_device(cluster: Cluster, devices: Sequence[int],
                         layers: int, model: ModelProfile,
                         task: Task, block_size: int = 0,
                         kv_dtype: Optional[str] = None) -> float:
    """C_mem^d: params + KV cache (sharded over the TP group) + 4 activation
    buffers. block_size > 0 accounts the KV term at paged-block granularity
    (serving.block_manager) instead of contiguous rows; kv_dtype reprices
    the cache term at the pool's storage precision (int8/fp8 pages)."""
    n = len(devices)
    B = task.bytes_per_el
    H = model.d_model
    s_total = task.s_in + task.s_out
    s_kv = _kv_tokens_per_seq(task, block_size)
    kv_b = model.kv_bytes_per_token_per_layer * _kv_width_factor(task,
                                                                 kv_dtype)
    per_layer = model.params_per_layer * B / n \
        + kv_b * task.batch * s_kv / n
    return per_layer * layers + 4 * task.batch * s_total * H * B


# Fraction of device memory actually usable for weights/caches (CUDA context,
# allocator fragmentation, workspace) — reproduces the paper's Fig.1 OOMs.
MEM_UTIL = 0.9


def mem_ok(cluster: Cluster, devices: Sequence[int], layers: int,
           model: ModelProfile, task: Task, block_size: int = 0,
           kv_dtype: Optional[str] = None) -> bool:
    need = mem_bytes_per_device(cluster, devices, layers, model, task,
                                block_size, kv_dtype)
    return all(need <= MEM_UTIL * cluster.devices[d].spec.mem_bytes
               for d in devices)


def concurrent_capacity(cluster: Cluster, devices: Sequence[int],
                        layers: int, model: ModelProfile, task: Task, *,
                        max_len: int = 0, block_size: int = 0,
                        prefix_hit_rate: float = 0.0,
                        kv_dtype: Optional[str] = None) -> int:
    """How many sequences of `task`'s shape fit in the memory left after
    parameters and activation buffers on this stage's TP group — the
    scheduler-facing capacity number behind the paged refactor.

    Contiguous (block_size == 0) reserves ``max_len`` tokens per sequence
    (worst case, defaulting to s_in + s_out); paged reserves only the
    blocks the sequence actually fills. The gap between the two IS the
    slots-vs-reservation win measured by benchmarks/bench_paged.py.

    prefix_hit_rate > 0 (paged + prefix caching) plans against the
    EFFECTIVE (deduplicated) per-sequence KV demand: shared prompt blocks
    are resident once regardless of how many in-flight sequences alias
    them, so a shared-system-prompt workload fits proportionally more
    concurrent sequences (benchmarks/bench_prefix.py measures the realized
    gap).

    kv_dtype reprices the per-sequence KV demand at the pool's storage
    precision: int8/fp8 pages fit ~2x the sequences of bf16 pools in the
    same free memory (benchmarks/bench_quant_kv.py measures the realized
    capacity gap).
    """
    n = len(devices)
    B = task.bytes_per_el
    free = min(MEM_UTIL * cluster.devices[d].spec.mem_bytes
               for d in devices)
    free -= model.params_per_layer * B / n * layers
    s_total = task.s_in + task.s_out
    free -= 4 * task.batch * s_total * model.d_model * B   # activations
    if free <= 0:
        return 0
    if block_size:
        toks = _kv_tokens_per_seq(task, block_size, prefix_hit_rate)
    else:
        toks = max(max_len, s_total)
    per_seq = model.kv_bytes_per_token_per_layer \
        * _kv_width_factor(task, kv_dtype) * toks * layers / n
    if per_seq <= 0:
        return 1 << 30              # recurrent-only stacks: O(1) state
    return int(free // per_seq)


# ---------------------------------------------------------------------------
# Phase-split costs (disaggregated prefill/decode, cf. HexGen-2/DistServe)
# ---------------------------------------------------------------------------
# The Table-1 terms above fold both inference phases into one latency; the
# role scheduler needs them APART, because the phases stress different
# hardware: prefill is one compute-bound pass over the prompt (weights
# scanned once, FLOPs over s_in tokens), decode scans the weights once per
# generated token. The split is a modeling choice, not an identity —
# comp_cost_phase("prefill") + comp_cost_phase("decode") differs from
# comp_cost by one weight scan, deliberately: the combined form charges the
# scan per output token only.

def comp_cost_phase(cluster: Cluster, devices: Sequence[int], layers: int,
                    model: ModelProfile, task: Task, phase: str) -> float:
    """One phase's compute time on a stage's TP group."""
    assert phase in ("prefill", "decode"), phase
    n = len(devices)
    B = task.bytes_per_el
    if phase == "prefill":
        scan = max(model.params_per_layer * B
                   / (n * cluster.devices[d].spec.mem_bw) for d in devices)
        flops = max(model.flops_per_layer_per_token * task.batch * task.s_in
                    / (n * cluster.devices[d].spec.flops) for d in devices)
    else:
        scan = max(model.params_per_layer * B * task.s_out
                   / (n * cluster.devices[d].spec.mem_bw) for d in devices)
        flops = max(model.flops_per_layer_per_token * task.batch * task.s_out
                    / (n * cluster.devices[d].spec.flops) for d in devices)
    return (scan + flops) * layers


def comm_pp_phase(cluster: Cluster, stage: Sequence[int],
                  next_stage: Sequence[int], task: Task,
                  model: ModelProfile, phase: str) -> float:
    """One phase's share of the stage-to-stage activation relay."""
    assert phase in ("prefill", "decode"), phase
    B = task.bytes_per_el
    H = model.d_model

    def best(msg_bytes: float) -> float:
        return min(cluster.lat[d, d2] + msg_bytes / cluster.bw[d, d2]
                   for d in stage for d2 in next_stage)

    if phase == "prefill":
        return best(task.batch * task.s_in * H * B)
    return best(task.batch * H * B) * task.s_out


@dataclasses.dataclass(frozen=True)
class PhaseCosts:
    """Per-phase latency (sum over stages) and bottleneck (max stage time)
    of one pipeline — the inputs to slo_sim.PhasedReplicaModel."""
    prefill_latency: float
    prefill_bottleneck: float
    decode_latency: float
    decode_bottleneck: float

    def as_dict(self) -> dict:
        """Plain-dict view for JSON surfaces (calibration reports,
        bench rows)."""
        return dataclasses.asdict(self)


def pipeline_phase_costs(cluster: Cluster, stages: List[Sequence[int]],
                         layer_split: List[int], model: ModelProfile,
                         task: Task) -> PhaseCosts:
    """Phase-split counterpart of pipeline_cost/pipeline_bottleneck."""
    out = {}
    for phase in ("prefill", "decode"):
        total, worst = 0.0, 0.0
        for j, (devs, l) in enumerate(zip(stages, layer_split)):
            t = comp_cost_phase(cluster, devs, l, model, task, phase) \
                + comm_tp_phase(cluster, devs, l, model, task, phase)
            if j + 1 < len(stages):
                t += comm_pp_phase(cluster, devs, stages[j + 1], task,
                                   model, phase)
            total += t
            worst = max(worst, t)
        out[phase] = (total, worst)
    return PhaseCosts(prefill_latency=out["prefill"][0],
                      prefill_bottleneck=out["prefill"][1],
                      decode_latency=out["decode"][0],
                      decode_bottleneck=out["decode"][1])


def phase_service_rates(pc: PhaseCosts) -> Tuple[float, float]:
    """One replica's per-phase service rates (requests/s): the edge
    capacities of the Helix-style max-flow graph (core.resched) — a
    prefill node admits 1/prefill_bottleneck req/s, a decode node
    completes 1/decode_bottleneck req/s."""
    return (1.0 / max(pc.prefill_bottleneck, 1e-12),
            1.0 / max(pc.decode_bottleneck, 1e-12))


def kv_migration_bytes(model: ModelProfile, task: Task,
                       block_size: int = 0,
                       kv_dtype: Optional[str] = None) -> float:
    """Wire size of one request's prefilled KV (every layer, the whole
    prompt, rounded up to whole blocks when paged): what a prefill->decode
    handoff ships over the modeled link. The wire carries the CACHE dtype
    — int8/fp8 pages ship their payload + float32 scales, ~1/4 the fp32
    bytes — so kv_dtype reprices the transfer, not just residency."""
    toks = task.s_in
    if block_size:
        toks = -(-toks // block_size) * block_size
    return model.kv_bytes_per_token_per_layer \
        * _kv_width_factor(task, kv_dtype) * toks * model.num_layers \
        * task.batch


# ---------------------------------------------------------------------------
# Host page tier + cluster prefix directory (serving.block_manager.
# HostPagePool / serving.cluster_kv): planner counterparts of tiered
# residency. The serving layer demotes evicted prefix blocks to host memory
# and fetches peer-resident prefixes over the KV link; the planner's job is
# to size those tiers and to turn residency into an ACHIEVABLE prefix hit
# rate instead of trusting a static scalar.
# ---------------------------------------------------------------------------

def kv_block_bytes(model: ModelProfile, task: Task, block_size: int,
                   kv_dtype: Optional[str] = None,
                   layers: Optional[int] = None) -> float:
    """Bytes one paged KV block occupies across ``layers`` (default: the
    whole stack) at the pool's storage precision — the granule every tier
    (device pool, host tier, cluster fetch) allocates and ships in."""
    L = model.num_layers if layers is None else layers
    return model.kv_bytes_per_token_per_layer \
        * _kv_width_factor(task, kv_dtype) * block_size * L


def host_tier_blocks(host_bytes: float, model: ModelProfile, task: Task,
                     block_size: int,
                     kv_dtype: Optional[str] = None) -> int:
    """How many paged KV blocks a host-memory budget holds (whole stack
    per block, at the pool's storage precision — quantized pools spill at
    their narrow width, so the same budget holds ~2-4x the int8 blocks)."""
    if host_bytes <= 0 or block_size <= 0:
        return 0
    return int(host_bytes // kv_block_bytes(model, task, block_size,
                                            kv_dtype))


def host_swap_seconds_per_block(model: ModelProfile, task: Task,
                                block_size: int, swap_gbps: float,
                                kv_dtype: Optional[str] = None) -> float:
    """Time to move one block over the host<->device (or peer-fetch) link
    at ``swap_gbps`` Gbit/s. <= 0 models an ideal (free) swap."""
    if swap_gbps <= 0:
        return 0.0
    return kv_block_bytes(model, task, block_size, kv_dtype) \
        / (swap_gbps * 1e9 / 8)


def device_pool_blocks(cluster: Cluster, devices: Sequence[int], layers: int,
                       model: ModelProfile, task: Task, block_size: int,
                       kv_dtype: Optional[str] = None) -> int:
    """Paged KV blocks one stage's TP group can pool after parameters and
    activation buffers: the device-tier residency bound feeding
    effective_prefix_hit_rate. concurrent_capacity divides the same free
    memory by SEQUENCES; this divides it by BLOCKS."""
    if block_size <= 0:
        return 0
    n = len(devices)
    B = task.bytes_per_el
    free = min(MEM_UTIL * cluster.devices[d].spec.mem_bytes
               for d in devices)
    free -= model.params_per_layer * B / n * layers
    free -= 4 * task.batch * (task.s_in + task.s_out) * model.d_model * B
    if free <= 0:
        return 0
    per_block = kv_block_bytes(model, task, block_size, kv_dtype,
                               layers=layers) / n
    if per_block <= 0:
        return 1 << 30              # recurrent-only stacks: O(1) state
    return int(free // per_block)


def effective_prefix_hit_rate(shareable: float, *, working_set_blocks: int,
                              device_blocks: int, host_blocks: int = 0,
                              peer_blocks: int = 0,
                              tier_discount: float = 0.0) -> float:
    """The cluster hit rate that replaces the static --prefix-hit-rate
    scalar: a prefix hit needs its blocks RESIDENT somewhere reachable, so
    the workload's shareable fraction (``shareable`` — the old static
    scalar, now an upper bound) is scaled by the fraction of the hot
    working set the replica can actually reach.

    Reach = its device pool + its host tier + peer-resident blocks behind
    the cluster directory. Tiered blocks (host + peer) are discounted by
    ``tier_discount`` in [0, 1]: the share of a tiered hit's saving eaten
    by swap/fetch time (1 = moving the block costs as much as recomputing
    it, so the tier is worthless for latency; 0 = free swap)."""
    if shareable <= 0.0:
        return 0.0
    if working_set_blocks <= 0:
        return min(shareable, 1.0)
    d = min(max(1.0 - tier_discount, 0.0), 1.0)
    reach = device_blocks + d * (host_blocks + peer_blocks)
    return min(shareable, 1.0) * min(1.0, reach / working_set_blocks)


# ---------------------------------------------------------------------------
# Speculative decoding (serving.spec): decode cost per COMMITTED token.
# Plain decode commits exactly one token per weight scan; a draft-then-
# verify step spends one target step plus k draft steps and commits the
# accepted prefix — between 1 and k + 1 tokens. The scheduler reasons in
# time per COMMITTED token, which is what SLO latency is made of.
# ---------------------------------------------------------------------------

def expected_commit_per_step(alpha: float, k: int) -> float:
    """Expected tokens committed per target verification step when each
    draft token is accepted independently with probability ``alpha`` and
    ``k`` drafts are proposed: 1 + alpha + ... + alpha^k (the bonus token
    always commits; draft j commits only if drafts 1..j all match).
    k = 0 is plain decode: exactly 1."""
    if k <= 0:
        return 1.0
    alpha = min(max(alpha, 0.0), 1.0)
    if alpha >= 1.0:
        return float(k + 1)
    return (1.0 - alpha ** (k + 1)) / (1.0 - alpha)


def spec_step_cost(step_cost: float, draft_step_cost: float, alpha: float,
                   k: int) -> float:
    """Decode time per COMMITTED token at speculation depth k: one target
    step (``step_cost``) plus k draft steps (``draft_step_cost`` each)
    commit ``expected_commit_per_step(alpha, k)`` tokens. k = 0 recovers
    ``step_cost`` exactly."""
    return (step_cost + k * draft_step_cost) \
        / expected_commit_per_step(alpha, k)


def best_spec_k(step_cost: float, draft_step_cost: float, alpha: float, *,
                max_k: int = 8) -> int:
    """Acceptance-aware speculation depth for ONE replica: the k in
    [0, max_k] minimizing decode time per committed token.

    The draft cost is ABSOLUTE, not a fraction of the target step — the
    tiny draft (or the host-side n-gram lookup) runs at roughly the same
    speed wherever it lives — so a SLOW replica (large ``step_cost``)
    amortizes each extra draft over a bigger saved step and picks DEEPER
    k. This is the per-replica knob the genetic search threads through
    ``SearchResult.spec_ks``. Ties keep the shallowest k (less draft work
    wasted when the realized acceptance rate drifts below ``alpha``)."""
    best, best_c = 0, spec_step_cost(step_cost, draft_step_cost, alpha, 0)
    for k in range(1, max_k + 1):
        c = spec_step_cost(step_cost, draft_step_cost, alpha, k)
        if c < best_c - 1e-12:
            best, best_c = k, c
    return best


# ---------------------------------------------------------------------------
# Whole-pipeline cost (Eq. 2)
# ---------------------------------------------------------------------------

def pipeline_cost(cluster: Cluster, stages: List[Sequence[int]],
                  layer_split: List[int], model: ModelProfile,
                  task: Task) -> float:
    """End-to-end latency; inf if any stage violates memory."""
    total = 0.0
    for j, (devs, l) in enumerate(zip(stages, layer_split)):
        if not mem_ok(cluster, devs, l, model, task):
            return float("inf")
        total += comp_cost(cluster, devs, l, model, task)
        total += comm_tp_cost(cluster, devs, l, model, task)
        if j + 1 < len(stages):
            total += comm_pp_cost(cluster, devs, stages[j + 1], task, model)
    return total


def pipeline_bottleneck(cluster: Cluster, stages: List[Sequence[int]],
                        layer_split: List[int], model: ModelProfile,
                        task: Task) -> float:
    """Max per-stage time: the pipelined throughput limit (1/this = req/s
    capacity of the replica when stages overlap across requests)."""
    worst = 0.0
    for j, (devs, l) in enumerate(zip(stages, layer_split)):
        t = comp_cost(cluster, devs, l, model, task) \
            + comm_tp_cost(cluster, devs, l, model, task)
        if j + 1 < len(stages):
            t += comm_pp_cost(cluster, devs, stages[j + 1], task, model)
        worst = max(worst, t)
    return worst
