"""Online rescheduling: drift detection + incremental re-solve + repair.

The genetic search (core.genetic) plans once against a static Task, but
production traffic drifts and replicas die. This module closes the loop
from observed serving statistics back into the scheduler:

- ``DriftDetector`` watches live admission/completion windows (arrival
  rate, prompt-length mix, speculative acceptance, replica liveness) and
  emits a ``DriftSignal`` when the observed workload leaves the band the
  incumbent plan was solved for.
- ``warm_resolve`` re-runs ``genetic.search`` seeded from the incumbent
  ``DeploymentPlan`` projected onto the surviving device pool — a few
  iterations refine an already-good plan instead of a cold search.
- ``repair_plan`` is the fast path for replica death: drop the dead
  replicas and re-pick the disaggregated role split by the Helix-style
  max-flow score (``flow_serve_rate``) over the phase-rate graph — no
  simulation, so it runs in microseconds between serve iterations.

The serving-side executor (serving.resched) diffs the incumbent and the
re-solved ``DeploymentPlan`` and migrates in-flight state; nothing here
touches live slots.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.cluster import Cluster
from repro.core.genetic import Individual, SearchResult, search
from repro.core.plan import DeploymentPlan, ReplicaSpec

# ---------------------------------------------------------------------------
# Drift detection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftSignal:
    """One detector firing: why the incumbent plan is suspect.

    kind: "rate_spike" | "mix_shift" | "acceptance_drift" |
    "replica_death" | "model_error"
    factor: observed / planned for the drifted quantity (rate or mean
    prompt length; acceptance reports observed alpha directly;
    model_error reports 1 + mean relative cost-model error).
    observed_rate / observed_prompt_len: the window estimates a re-solve
    should plan against (0 when the window was empty).
    dead: replica keys (device-id frozensets) confirmed dead, if any.
    phase: the worst-calibrated phase, for model_error signals.
    """

    kind: str
    at: float
    factor: float = 1.0
    observed_rate: float = 0.0
    observed_prompt_len: float = 0.0
    observed_alpha: float = 0.0
    dead: Tuple[FrozenSet[int], ...] = ()
    phase: str = ""

    def describe(self) -> str:
        if self.kind == "replica_death":
            return f"replica_death x{len(self.dead)}"
        if self.kind == "model_error" and self.phase:
            return f"model_error factor={self.factor:.2f} " \
                   f"worst={self.phase}"
        return f"{self.kind} factor={self.factor:.2f}"


class DriftDetector:
    """Windowed drift detector over live serving observations.

    The router calls ``observe_admit(now, prompt_len)`` per dispatched
    request and ``observe_spec(proposed, accepted)`` with counter deltas;
    the executor calls ``observe_death(key)`` when a replica dies.
    ``poll(now)`` returns the highest-priority pending ``DriftSignal`` (or
    None) and RE-ANCHORS the fired dimension so one sustained shift
    triggers one re-solve, not one per iteration.

    Thresholds are deliberately coarse: a re-solve costs a warm genetic
    search plus live migrations, so only leave-the-band drift (default 3x
    rate, 2x mean prompt length, alpha off by > 0.25) is worth it.
    """

    def __init__(self, *, rate: float, prompt_len: float = 0.0,
                 spec_alpha: float = 0.0, window: float = 10.0,
                 min_events: int = 8, rate_threshold: float = 3.0,
                 mix_threshold: float = 2.0,
                 alpha_slack: float = 0.25,
                 model_error_threshold: float = 0.5,
                 model_error_min: int = 2):
        assert rate > 0.0, rate
        self.planned_rate = rate
        self.planned_prompt_len = prompt_len
        self.planned_alpha = spec_alpha
        self.window = window
        self.min_events = min_events
        self.rate_threshold = rate_threshold
        self.mix_threshold = mix_threshold
        self.alpha_slack = alpha_slack
        self.model_error_threshold = model_error_threshold
        self.model_error_min = model_error_min
        self._admits: Deque[Tuple[float, int]] = collections.deque()
        self._spec_proposed = 0
        self._spec_accepted = 0
        self._dead: List[FrozenSet[int]] = []
        self._model_errors: List[Tuple[str, float]] = []
        self.signals_fired: List[DriftSignal] = []

    # ---- observations ----------------------------------------------------
    def observe_admit(self, now: float, prompt_len: int) -> None:
        self._admits.append((now, int(prompt_len)))
        self._trim(now)

    def observe_spec(self, proposed: int, accepted: int) -> None:
        self._spec_proposed += int(proposed)
        self._spec_accepted += int(accepted)

    def observe_death(self, key: FrozenSet[int]) -> None:
        if key not in self._dead:
            self._dead.append(frozenset(key))

    def observe_model_error(self, phase: str, predicted: float,
                            observed: float) -> None:
        """One calibration row (repro.obs.calibration.CostCalibrator.feed):
        how far a phase's observed seconds/unit landed from the cost
        model's prediction the incumbent plan was scored with."""
        if predicted > 0.0:
            rel = abs(observed - predicted) / predicted
            self._model_errors.append((phase, rel))

    def _trim(self, now: float) -> None:
        w = self._admits
        while w and w[0][0] < now - self.window:
            w.popleft()

    # ---- window estimates ------------------------------------------------
    def window_rate(self, now: float) -> float:
        self._trim(now)
        if not self._admits:
            return 0.0
        span = max(now - self._admits[0][0], 1e-9)
        return len(self._admits) / span

    def window_prompt_len(self, now: float) -> float:
        self._trim(now)
        if not self._admits:
            return 0.0
        return float(np.mean([n for _, n in self._admits]))

    def window_alpha(self) -> float:
        if self._spec_proposed <= 0:
            return self.planned_alpha
        return self._spec_accepted / self._spec_proposed

    def window_model_error(self) -> float:
        if not self._model_errors:
            return 0.0
        return float(np.mean([e for _, e in self._model_errors]))

    # ---- the trigger -----------------------------------------------------
    def poll(self, now: float) -> Optional[DriftSignal]:
        sig = self._poll(now)
        if sig is not None:
            self.signals_fired.append(sig)
        return sig

    def _poll(self, now: float) -> Optional[DriftSignal]:
        # liveness first: a dead replica is an immediate repair, not a
        # statistics question
        if self._dead:
            dead = tuple(self._dead)
            self._dead.clear()
            return DriftSignal(kind="replica_death", at=now,
                               factor=float(len(dead)), dead=dead,
                               observed_rate=self.window_rate(now),
                               observed_prompt_len=self
                               .window_prompt_len(now))
        if len(self._admits) >= self.min_events:
            rate = self.window_rate(now)
            if rate > 0.0:
                f = rate / self.planned_rate
                if f >= self.rate_threshold \
                        or f <= 1.0 / self.rate_threshold:
                    self.planned_rate = rate      # re-anchor: fire once
                    return DriftSignal(kind="rate_spike", at=now, factor=f,
                                       observed_rate=rate,
                                       observed_prompt_len=self
                                       .window_prompt_len(now))
            plen = self.window_prompt_len(now)
            if self.planned_prompt_len > 0.0 and plen > 0.0:
                f = plen / self.planned_prompt_len
                if f >= self.mix_threshold \
                        or f <= 1.0 / self.mix_threshold:
                    self.planned_prompt_len = plen
                    return DriftSignal(kind="mix_shift", at=now, factor=f,
                                       observed_rate=rate,
                                       observed_prompt_len=plen)
            if self.planned_alpha > 0.0 and self._spec_proposed >= \
                    self.min_events:
                alpha = self.window_alpha()
                if abs(alpha - self.planned_alpha) > self.alpha_slack:
                    base = self.planned_alpha
                    self.planned_alpha = alpha
                    self._spec_proposed = self._spec_accepted = 0
                    return DriftSignal(kind="acceptance_drift", at=now,
                                       factor=alpha / max(base, 1e-9),
                                       observed_rate=rate,
                                       observed_alpha=alpha)
        # calibration drift, lowest priority: the cost model the incumbent
        # plan was scored with no longer matches observed phase costs —
        # traffic may look in-band while every placement score is stale
        if len(self._model_errors) >= self.model_error_min:
            err = self.window_model_error()
            if err > self.model_error_threshold:
                worst = max(self._model_errors, key=lambda pe: pe[1])[0]
                self._model_errors.clear()        # re-anchor: fire once
                return DriftSignal(kind="model_error", at=now,
                                   factor=1.0 + err,
                                   observed_rate=self.window_rate(now),
                                   observed_prompt_len=self
                                   .window_prompt_len(now),
                                   phase=worst)
        return None


# ---------------------------------------------------------------------------
# Helix-style max-flow over the phase-rate graph
# ---------------------------------------------------------------------------

def max_flow(cap: np.ndarray, s: int, t: int) -> float:
    """Edmonds-Karp on a dense capacity matrix (the graphs here have a
    handful of replica nodes, so O(V * E^2) is microseconds)."""
    n = cap.shape[0]
    resid = cap.astype(float).copy()
    flow = 0.0
    while True:
        # BFS for the shortest augmenting path
        parent = np.full(n, -1, dtype=int)
        parent[s] = s
        q: Deque[int] = collections.deque([s])
        while q and parent[t] == -1:
            u = q.popleft()
            for v in range(n):
                if parent[v] == -1 and resid[u, v] > 1e-12:
                    parent[v] = u
                    q.append(v)
        if parent[t] == -1:
            return flow
        # bottleneck along the path
        push = float("inf")
        v = t
        while v != s:
            u = int(parent[v])
            push = min(push, resid[u, v])
            v = u
        v = t
        while v != s:
            u = int(parent[v])
            resid[u, v] -= push
            resid[v, u] += push
            v = u
        flow += push


def flow_serve_rate(prefill_rates: Sequence[float],
                    decode_rates: Sequence[float],
                    link_rates: Optional[np.ndarray] = None) -> float:
    """Sustainable request rate of a disaggregated replica set as the
    max flow source -> prefill nodes -> links -> decode nodes -> sink
    (Helix, PAPERS.md: heterogeneous serving as max-flow over the
    GPU/network graph). Rates are requests/second; ``link_rates[i, j]``
    caps the prefill-i -> decode-j handoff (None = unconstrained wire).
    """
    np_, nd = len(prefill_rates), len(decode_rates)
    if np_ == 0 or nd == 0:
        return 0.0
    # nodes: 0 = source, 1..np_ = prefill, np_+1..np_+nd = decode, last = sink
    n = np_ + nd + 2
    t = n - 1
    cap = np.zeros((n, n))
    for i, r in enumerate(prefill_rates):
        cap[0, 1 + i] = max(float(r), 0.0)
    for j, r in enumerate(decode_rates):
        cap[1 + np_ + j, t] = max(float(r), 0.0)
    for i in range(np_):
        for j in range(nd):
            w = float(link_rates[i, j]) if link_rates is not None \
                else float("inf")
            cap[1 + i, 1 + np_ + j] = max(w, 0.0)
    # inf capacities break the residual arithmetic; clamp to the total
    # achievable flow, which no single edge can exceed
    lim = sum(cap[0, 1:1 + np_])
    cap = np.minimum(cap, lim if lim > 0 else 1.0)
    return max_flow(cap, 0, t)


def colocated_serve_rate(models: Sequence[slo_sim.PhasedReplicaModel]
                         ) -> float:
    """Flow-equivalent score for colocated serving: every replica turns
    requests over its combined bottleneck independently."""
    return sum(1.0 / max(m.prefill_bottleneck + m.decode_bottleneck, 1e-12)
               for m in models)


def phase_rates(models: Sequence[slo_sim.PhasedReplicaModel]
                ) -> Tuple[List[float], List[float]]:
    """Per-replica phase service rates (requests/s) for the flow graph."""
    pre = [1.0 / max(m.prefill_bottleneck, 1e-12) for m in models]
    dec = [1.0 / max(m.decode_bottleneck, 1e-12) for m in models]
    return pre, dec


def flow_role_split(models: Sequence[slo_sim.PhasedReplicaModel], *,
                    kv_bytes: float = 0.0,
                    link_bw: float = float("inf")
                    ) -> Tuple[Optional[List[str]], float]:
    """Fast role repair: pick the prefill/decode split maximizing the
    max-flow serve rate instead of running the SLO simulator. Candidates
    follow the comparative-advantage order genetic.best_role_split uses
    (smallest prefill/decode bottleneck ratio first), plus the colocated
    all-"both" fallback — which wins whenever any split's flow is lower,
    e.g. when every survivor is on one side of the graph.

    Returns (roles, rate); roles is None when colocated wins."""
    n = len(models)
    pre_r, dec_r = phase_rates(models)
    best_roles: Optional[List[str]] = None
    best_rate = colocated_serve_rate(models)
    if n < 2:
        return None, best_rate
    wire = kv_bytes / link_bw if np.isfinite(link_bw) and link_bw > 0 \
        else 0.0
    order = sorted(range(n), key=lambda i: (
        models[i].prefill_bottleneck
        / max(models[i].decode_bottleneck, 1e-12), i))
    for k in range(1, n):
        pre = set(order[:k])
        prates = [pre_r[i] for i in range(n) if i in pre]
        drates = [dec_r[j] for j in range(n) if j not in pre]
        links = None
        if wire > 0.0:
            # one handoff occupies the wire for `wire` seconds
            links = np.full((len(prates), len(drates)), 1.0 / wire)
        rate = flow_serve_rate(prates, drates, links)
        if rate > best_rate:
            best_rate = rate
            best_roles = ["prefill" if i in pre else "decode"
                          for i in range(n)]
    return best_roles, best_rate


# ---------------------------------------------------------------------------
# Fast repair + warm re-solve
# ---------------------------------------------------------------------------

def repair_plan(plan: DeploymentPlan,
                dead: Sequence[FrozenSet[int]], *,
                models: Optional[Sequence[slo_sim.PhasedReplicaModel]]
                = None, kv_bytes: float = 0.0,
                link_bw: float = float("inf")) -> DeploymentPlan:
    """Greedy/flow repair for replica death: drop the dead replicas and,
    if the plan was disaggregated, re-pick the survivors' role split by
    max-flow score (``models`` aligned with the SURVIVING replicas; omit
    them to fall back to all-"both", which is always token-safe).

    This is the fast path the executor takes the instant a replica dies
    — a full warm re-solve can follow asynchronously."""
    gone = {frozenset(k) for k in dead}
    survivors = [r for r in plan.replicas if r.key not in gone]
    dims = plan.dims
    if "roles" in dims and survivors:
        roles: Optional[List[str]] = None
        if models is not None:
            assert len(models) == len(survivors), \
                (len(models), len(survivors))
            roles, _ = flow_role_split(models, kv_bytes=kv_bytes,
                                       link_bw=link_bw)
        if roles is None:
            # colocated fallback: every survivor serves end to end —
            # never leaves prefill-only or decode-only islands behind
            roles = ["both"] * len(survivors)
        survivors = [dataclasses.replace(r, role=roles[i])
                     for i, r in enumerate(survivors)]
    return DeploymentPlan(replicas=survivors, dims=dims).canonical()


def drop_devices(cluster: Cluster, drop: Sequence[int]
                 ) -> Tuple[Cluster, Dict[int, int]]:
    """The surviving pool after ``drop`` device ids die, plus the
    old-id -> new-id map (devices are renumbered contiguously)."""
    dead = set(drop)
    keep = [d for d in cluster.devices if d.id not in dead]
    remap = {d.id: i for i, d in enumerate(keep)}
    devs = [cl.Device(remap[d.id], d.type, d.machine, d.region)
            for d in keep]
    idx = [d.id for d in keep]
    return Cluster(devs, cluster.lat[np.ix_(idx, idx)],
                   cluster.bw[np.ix_(idx, idx)]), remap


def warm_seed(plan: DeploymentPlan, remap: Dict[int, int],
              pool_size: int) -> Individual:
    """The incumbent plan projected onto the surviving pool as a genetic
    individual: each replica's surviving devices stay one group, and
    devices the incumbent never used form one extra group so the search
    can grow into them."""
    groups: List[FrozenSet[int]] = []
    for r in plan.replicas:
        g = frozenset(remap[d] for d in r.device_ids if d in remap)
        if g:
            groups.append(g)
    assigned = {d for g in groups for d in g}
    rest = frozenset(set(range(pool_size)) - assigned)
    if rest:
        groups.append(rest)
    return tuple(sorted(groups, key=lambda g: sorted(g)))


def warm_resolve(cluster: Cluster, model: cm.ModelProfile, task: cm.Task,
                 *, incumbent: DeploymentPlan, deadline: float,
                 rate: float, dead_devices: Sequence[int] = (),
                 iters: int = 8, seed: int = 1,
                 **search_kw) -> Tuple[SearchResult, Dict[int, int]]:
    """Incremental re-solve: project the incumbent onto the pool minus
    ``dead_devices`` and run a SHORT genetic search seeded from it
    (init=[warm]) against the OBSERVED rate/task. Returns the result and
    the old-id -> new-id device map (identity when nothing died) so the
    caller can translate the new plan back into live replica identities.
    """
    if dead_devices:
        pool, remap = drop_devices(cluster, dead_devices)
    else:
        pool, remap = cluster, {d.id: d.id for d in cluster.devices}
    warm = warm_seed(incumbent, remap, len(pool))
    res = search(pool, model, task, deadline=deadline, rate=rate,
                 iters=iters, seed=seed, init=[warm] if warm else None,
                 **search_kw)
    return res, remap
