"""AlpaServe-style inference workload simulator (§4.3 "Put it together",
§5.1 evaluation metrics).

Requests arrive by a Poisson process (exponential inter-arrival, rate
lambda); each request is dispatched to the replica whose queue admits it
earliest; a replica is a pipeline that admits a new request every
`bottleneck` seconds (stages overlap across requests) and completes it
`latency` seconds after admission. SLO attainment = fraction of requests
finishing within the deadline.

The simulator is the shared serving loop (serving.loop) on a virtual clock,
with each pipeline modeled as a closed-form analytic worker — the SAME
admission policy and accounting that serve real replicas, so simulated and
measured attainment stay comparable by construction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class ReplicaModel:
    latency: float        # end-to-end time of one request on this pipeline
    bottleneck: float     # min inter-admission gap (max stage time)
    # in-flight request bound from KV-cache capacity (0 = unbounded, the
    # paper's idealized queue). cost_model.concurrent_capacity derives it
    # for either layout; the paged layout's larger bound — and the further
    # deduplication from prefix caching (prefix_hit_rate) — shows up
    # directly as simulated attainment.
    max_concurrent: int = 0


def poisson_arrivals(rate: float, duration: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ts = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        ts.append(t)
    return np.asarray(ts)


def piecewise_poisson_arrivals(segments: Sequence[Tuple[float, float]],
                               seed: int = 0) -> np.ndarray:
    """Arrival times of a piecewise-constant-rate Poisson process:
    ``segments`` is a list of (rate, duration) legs played back to back —
    the chaos benchmark's 10x spike is [(r, t0), (10 * r, t1), (r, t2)].
    Rate-0 legs contribute silence."""
    rng = np.random.default_rng(seed)
    ts: List[float] = []
    t0 = 0.0
    for rate, duration in segments:
        assert duration >= 0.0, duration
        if rate > 0.0:
            t = t0
            while True:
                t += rng.exponential(1.0 / rate)
                if t > t0 + duration:
                    break
                ts.append(t)
        t0 += duration
    return np.asarray(ts)


class AnalyticWorker:
    """Closed-form pipeline model as a serve-loop worker: admission every
    `bottleneck` seconds, completion `latency` seconds after admission."""

    def __init__(self, model: ReplicaModel):
        self.model = model
        self.next_admit = 0.0
        self._events: List = []    # heap of (finish_time, order, request)
        self._order = 0

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        if self.model.max_concurrent:
            return max(self.model.max_concurrent - len(self._events), 0)
        return 1 << 30             # unbounded queue, like the paper's sim

    def load(self, now: float) -> float:
        # earliest possible completion for the next admitted request
        return max(self.next_admit, now) + self.model.latency

    def admit(self, reqs, now: float) -> None:
        for r in reqs:
            start = max(self.next_admit, now)
            finish = start + self.model.latency
            self.next_admit = start + self.model.bottleneck
            heapq.heappush(self._events, (finish, self._order, r))
            self._order += 1

    def busy(self, now: float) -> bool:
        return bool(self._events) and self._events[0][0] <= now

    def inflight(self) -> int:
        return len(self._events)

    def next_event(self, now: float):
        return self._events[0][0] if self._events else None

    def run_iteration(self, now: float):
        comps = []
        while self._events and self._events[0][0] <= now:
            finish, _, req = heapq.heappop(self._events)
            comps.append((req, None, finish))
        return comps, 0.0


@dataclasses.dataclass(frozen=True)
class PhasedReplicaModel:
    """A replica with its two inference phases costed separately
    (cost_model.pipeline_phase_costs) — the scheduler's disaggregation
    unit. ``colocated()`` collapses it back into the single-phase
    ReplicaModel: one request costs prefill + decode end to end, and the
    replica turns requests over one combined bottleneck apart."""
    prefill_latency: float
    prefill_bottleneck: float
    decode_latency: float
    decode_bottleneck: float
    max_concurrent: int = 0

    def colocated(self) -> ReplicaModel:
        return ReplicaModel(
            latency=self.prefill_latency + self.decode_latency,
            bottleneck=self.prefill_bottleneck + self.decode_bottleneck,
            max_concurrent=self.max_concurrent)

    def with_spec(self, multiplier: float) -> "PhasedReplicaModel":
        """Speculative decoding makes the worker consume its decode phase
        in MULTI-TOKEN COMMITS: per committed token the replica spends
        ``multiplier`` of its plain per-token decode time (< 1 when
        speculation wins — cost_model.spec_step_cost over the plain step
        cost), so the whole decode phase scales by that factor while
        prefill is untouched. The scaled model feeds the same analytic
        workers; the scheduler picks the per-replica depth behind the
        multiplier (cost_model.best_spec_k via genetic.choose_spec_ks)."""
        assert multiplier > 0.0, multiplier
        return dataclasses.replace(
            self, decode_latency=self.decode_latency * multiplier,
            decode_bottleneck=self.decode_bottleneck * multiplier)


class AnalyticPrefillWorker:
    """Prefill-role analytic replica: admits arrivals at its prefill
    bottleneck cadence, and `prefill_latency` later hands each request to
    the least-loaded decode worker with the modeled transfer delay — no
    completions of its own."""

    def __init__(self, model: PhasedReplicaModel, idx: int):
        self.model = model
        self.idx = idx
        self.targets: List["AnalyticDecodeWorker"] = []   # wired by sim
        self.delay_fn: Callable[[int, int], float] = lambda i, j: 0.0
        self.next_admit = 0.0
        self._events: List = []    # heap of (prefill_done, order, request)
        self._order = 0

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        if self.model.max_concurrent:
            return max(self.model.max_concurrent - len(self._events), 0)
        return 1 << 30

    def load(self, now: float) -> float:
        return max(self.next_admit, now) + self.model.prefill_latency

    def admit(self, reqs, now: float) -> None:
        for r in reqs:
            start = max(self.next_admit, now)
            done = start + self.model.prefill_latency
            self.next_admit = start + self.model.prefill_bottleneck
            heapq.heappush(self._events, (done, self._order, r))
            self._order += 1

    def busy(self, now: float) -> bool:
        return bool(self._events) and self._events[0][0] <= now

    def inflight(self) -> int:
        return len(self._events)

    def next_event(self, now: float):
        return self._events[0][0] if self._events else None

    def run_iteration(self, now: float):
        while self._events and self._events[0][0] <= now:
            done, _, req = heapq.heappop(self._events)
            dst = min(self.targets, key=lambda w: (w.queue_depth(), w.idx))
            req.prefill_finish_time = done
            dst.migrate_in(req, done + self.delay_fn(self.idx, dst.idx))
        return [], 0.0


class AnalyticDecodeWorker:
    """Decode-role analytic replica: admits nothing from the router
    (capacity 0); migrated requests become eligible at their transfer
    arrival time, start decoding at the decode-bottleneck cadence (bounded
    by KV capacity), and complete `decode_latency` after starting."""

    def __init__(self, model: PhasedReplicaModel, idx: int):
        self.model = model
        self.idx = idx
        self.next_admit = 0.0
        self._pending: List = []   # heap of (ready_time, order, request)
        self._events: List = []    # heap of (finish_time, order, request)
        self._order = 0

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        return 0                   # work arrives only via migrate_in

    def load(self, now: float) -> float:
        return max(self.next_admit, now) + self.model.decode_latency

    def queue_depth(self) -> int:
        return len(self._pending) + len(self._events)

    def migrate_in(self, req, ready: float) -> None:
        heapq.heappush(self._pending, (ready, self._order, req))
        self._order += 1

    def _admittable(self, now: float) -> bool:
        if not self._pending or self._pending[0][0] > now:
            return False
        return not self.model.max_concurrent \
            or len(self._events) < self.model.max_concurrent

    def busy(self, now: float) -> bool:
        if self._admittable(now):
            return True
        return bool(self._events) and self._events[0][0] <= now

    def inflight(self) -> int:
        return self.queue_depth()

    def next_event(self, now: float):
        ts = []
        if self._pending:
            ts.append(self._pending[0][0])
        if self._events:
            ts.append(self._events[0][0])
        return min(ts) if ts else None

    def run_iteration(self, now: float):
        while self._admittable(now):
            ready, _, req = heapq.heappop(self._pending)
            start = max(self.next_admit, ready, now)
            finish = start + self.model.decode_latency
            self.next_admit = start + self.model.decode_bottleneck
            heapq.heappush(self._events, (finish, self._order, req))
            self._order += 1
        comps = []
        while self._events and self._events[0][0] <= now:
            finish, _, req = heapq.heappop(self._events)
            comps.append((req, None, finish))
        return comps, 0.0


_EMPTY_PROMPT = np.zeros((0,), np.int32)


def simulate(replicas: Sequence[ReplicaModel], rate: float, deadline: float,
             *, duration: float = 120.0, seed: int = 0) -> float:
    """Returns SLO attainment in [0, 1]."""
    if not replicas:
        return 0.0
    arrivals = poisson_arrivals(rate, duration, seed)
    if len(arrivals) == 0:
        return 1.0
    workers = [AnalyticWorker(rep) for rep in replicas]
    reqs = [Request(rid=i, prompt=_EMPTY_PROMPT, max_new_tokens=0, arrival=t)
            for i, t in enumerate(arrivals)]
    stats = run_serve_loop(workers, reqs, deadline=deadline,
                           clock=VirtualClock())
    return stats.attainment


def simulate_disagg(models: Sequence[PhasedReplicaModel],
                    roles: Sequence[str], rate: float, deadline: float, *,
                    kv_bytes: float = 0.0, link_bw: float = float("inf"),
                    link_lat: float = 0.0,
                    delay_fn: Optional[Callable[[int, int], float]] = None,
                    duration: float = 120.0, seed: int = 0) -> float:
    """SLO attainment of a ROLE-TAGGED replica set on the shared loop:
    "both" replicas serve end to end; "prefill" replicas hand finished
    prefills to the least-loaded "decode" replica after the transfer
    delay (``delay_fn(src, dst)``, defaulting to the flat
    ``link_lat + kv_bytes / link_bw``). Same arrivals, admission policy
    and accounting as ``simulate`` — the colocated and disaggregated
    numbers are comparable by construction."""
    assert len(models) == len(roles)
    if not models:
        return 0.0
    if delay_fn is None:
        flat = link_lat + (kv_bytes / link_bw
                           if np.isfinite(link_bw) else 0.0)
        delay_fn = lambda i, j: flat                          # noqa: E731
    workers = []
    for i, (m, role) in enumerate(zip(models, roles)):
        assert role in ("both", "prefill", "decode"), role
        if role == "both":
            workers.append(AnalyticWorker(m.colocated()))
        elif role == "prefill":
            workers.append(AnalyticPrefillWorker(m, i))
        else:
            workers.append(AnalyticDecodeWorker(m, i))
    prefills = [w for w in workers if isinstance(w, AnalyticPrefillWorker)]
    decodes = [w for w in workers if isinstance(w, AnalyticDecodeWorker)]
    assert bool(prefills) == bool(decodes), \
        f"need both phases covered (or neither): {list(roles)}"
    for w in prefills:
        w.targets = decodes
        w.delay_fn = delay_fn
    arrivals = poisson_arrivals(rate, duration, seed)
    if len(arrivals) == 0:
        return 1.0
    reqs = [Request(rid=i, prompt=_EMPTY_PROMPT, max_new_tokens=0, arrival=t)
            for i, t in enumerate(arrivals)]
    stats = run_serve_loop(workers, reqs, deadline=deadline,
                           clock=VirtualClock())
    return stats.attainment


def attainment_curve(replicas: Sequence[ReplicaModel], rates: Sequence[float],
                     deadline: float, **kw) -> List[float]:
    return [simulate(replicas, r, deadline, **kw) for r in rates]


def min_deadline_for_attainment(replicas: Sequence[ReplicaModel], rate: float,
                                target: float = 0.99, *, duration: float = 120.0,
                                seed: int = 0, hi: float = 1e4) -> float:
    """Smallest deadline achieving `target` attainment (bisection)."""
    lo = 0.0
    hi0 = hi
    if simulate(replicas, rate, hi0, duration=duration, seed=seed) < target:
        return float("inf")
    for _ in range(40):
        mid = (lo + hi) / 2
        if simulate(replicas, rate, mid, duration=duration, seed=seed) >= target:
            hi = mid
        else:
            lo = mid
    return hi


def peak_rate_for_attainment(replicas: Sequence[ReplicaModel],
                             deadline: float, target: float = 0.99, *,
                             duration: float = 120.0, seed: int = 0,
                             hi: float = 64.0) -> float:
    """Largest request rate sustaining `target` attainment (bisection)."""
    if simulate(replicas, 1e-3, deadline, duration=duration, seed=seed) < target:
        return 0.0
    lo = 1e-3
    while simulate(replicas, hi, deadline, duration=duration, seed=seed) >= target:
        hi *= 2
        if hi > 1e5:
            return hi
    for _ in range(30):
        mid = (lo + hi) / 2
        if simulate(replicas, mid, deadline, duration=duration, seed=seed) >= target:
            lo = mid
        else:
            hi = mid
    return lo
