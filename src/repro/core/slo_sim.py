"""AlpaServe-style inference workload simulator (§4.3 "Put it together",
§5.1 evaluation metrics).

Requests arrive by a Poisson process (exponential inter-arrival, rate
lambda); each request is dispatched to the replica whose queue admits it
earliest; a replica is a pipeline that admits a new request every
`bottleneck` seconds (stages overlap across requests) and completes it
`latency` seconds after admission. SLO attainment = fraction of requests
finishing within the deadline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReplicaModel:
    latency: float        # end-to-end time of one request on this pipeline
    bottleneck: float     # min inter-admission gap (max stage time)


def poisson_arrivals(rate: float, duration: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ts = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        ts.append(t)
    return np.asarray(ts)


def simulate(replicas: Sequence[ReplicaModel], rate: float, deadline: float,
             *, duration: float = 120.0, seed: int = 0) -> float:
    """Returns SLO attainment in [0, 1]."""
    if not replicas:
        return 0.0
    arrivals = poisson_arrivals(rate, duration, seed)
    if len(arrivals) == 0:
        return 1.0
    next_free = np.zeros(len(replicas))
    ok = 0
    for t in arrivals:
        # least-loaded dispatch: earliest possible admission
        starts = np.maximum(next_free, t)
        r = int(np.argmin(starts + [rep.latency for rep in replicas]))
        start = max(next_free[r], t)
        finish = start + replicas[r].latency
        next_free[r] = start + replicas[r].bottleneck
        if finish - t <= deadline:
            ok += 1
    return ok / len(arrivals)


def attainment_curve(replicas: Sequence[ReplicaModel], rates: Sequence[float],
                     deadline: float, **kw) -> List[float]:
    return [simulate(replicas, r, deadline, **kw) for r in rates]


def min_deadline_for_attainment(replicas: Sequence[ReplicaModel], rate: float,
                                target: float = 0.99, *, duration: float = 120.0,
                                seed: int = 0, hi: float = 1e4) -> float:
    """Smallest deadline achieving `target` attainment (bisection)."""
    lo = 0.0
    hi0 = hi
    if simulate(replicas, rate, hi0, duration=duration, seed=seed) < target:
        return float("inf")
    for _ in range(40):
        mid = (lo + hi) / 2
        if simulate(replicas, rate, mid, duration=duration, seed=seed) >= target:
            hi = mid
        else:
            lo = mid
    return hi


def peak_rate_for_attainment(replicas: Sequence[ReplicaModel],
                             deadline: float, target: float = 0.99, *,
                             duration: float = 120.0, seed: int = 0,
                             hi: float = 64.0) -> float:
    """Largest request rate sustaining `target` attainment (bisection)."""
    if simulate(replicas, 1e-3, deadline, duration=duration, seed=seed) < target:
        return 0.0
    lo = 1e-3
    while simulate(replicas, hi, deadline, duration=duration, seed=seed) >= target:
        hi *= 2
        if hi > 1e5:
            return hi
    for _ in range(30):
        mid = (lo + hi) / 2
        if simulate(replicas, mid, deadline, duration=duration, seed=seed) >= target:
            lo = mid
        else:
            hi = mid
    return lo
