"""AlpaServe-style inference workload simulator (§4.3 "Put it together",
§5.1 evaluation metrics).

Requests arrive by a Poisson process (exponential inter-arrival, rate
lambda); each request is dispatched to the replica whose queue admits it
earliest; a replica is a pipeline that admits a new request every
`bottleneck` seconds (stages overlap across requests) and completes it
`latency` seconds after admission. SLO attainment = fraction of requests
finishing within the deadline.

The simulator is the shared serving loop (serving.loop) on a virtual clock,
with each pipeline modeled as a closed-form analytic worker — the SAME
admission policy and accounting that serve real replicas, so simulated and
measured attainment stay comparable by construction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Sequence

import numpy as np

from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class ReplicaModel:
    latency: float        # end-to-end time of one request on this pipeline
    bottleneck: float     # min inter-admission gap (max stage time)
    # in-flight request bound from KV-cache capacity (0 = unbounded, the
    # paper's idealized queue). cost_model.concurrent_capacity derives it
    # for either layout; the paged layout's larger bound — and the further
    # deduplication from prefix caching (prefix_hit_rate) — shows up
    # directly as simulated attainment.
    max_concurrent: int = 0


def poisson_arrivals(rate: float, duration: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ts = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        ts.append(t)
    return np.asarray(ts)


class AnalyticWorker:
    """Closed-form pipeline model as a serve-loop worker: admission every
    `bottleneck` seconds, completion `latency` seconds after admission."""

    def __init__(self, model: ReplicaModel):
        self.model = model
        self.next_admit = 0.0
        self._events: List = []    # heap of (finish_time, order, request)
        self._order = 0

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        if self.model.max_concurrent:
            return max(self.model.max_concurrent - len(self._events), 0)
        return 1 << 30             # unbounded queue, like the paper's sim

    def load(self, now: float) -> float:
        # earliest possible completion for the next admitted request
        return max(self.next_admit, now) + self.model.latency

    def admit(self, reqs, now: float) -> None:
        for r in reqs:
            start = max(self.next_admit, now)
            finish = start + self.model.latency
            self.next_admit = start + self.model.bottleneck
            heapq.heappush(self._events, (finish, self._order, r))
            self._order += 1

    def busy(self, now: float) -> bool:
        return bool(self._events) and self._events[0][0] <= now

    def inflight(self) -> int:
        return len(self._events)

    def next_event(self, now: float):
        return self._events[0][0] if self._events else None

    def run_iteration(self, now: float):
        comps = []
        while self._events and self._events[0][0] <= now:
            finish, _, req = heapq.heappop(self._events)
            comps.append((req, None, finish))
        return comps, 0.0


_EMPTY_PROMPT = np.zeros((0,), np.int32)


def simulate(replicas: Sequence[ReplicaModel], rate: float, deadline: float,
             *, duration: float = 120.0, seed: int = 0) -> float:
    """Returns SLO attainment in [0, 1]."""
    if not replicas:
        return 0.0
    arrivals = poisson_arrivals(rate, duration, seed)
    if len(arrivals) == 0:
        return 1.0
    workers = [AnalyticWorker(rep) for rep in replicas]
    reqs = [Request(rid=i, prompt=_EMPTY_PROMPT, max_new_tokens=0, arrival=t)
            for i, t in enumerate(arrivals)]
    stats = run_serve_loop(workers, reqs, deadline=deadline,
                           clock=VirtualClock())
    return stats.attainment


def attainment_curve(replicas: Sequence[ReplicaModel], rates: Sequence[float],
                     deadline: float, **kw) -> List[float]:
    return [simulate(replicas, r, deadline, **kw) for r in rates]


def min_deadline_for_attainment(replicas: Sequence[ReplicaModel], rate: float,
                                target: float = 0.99, *, duration: float = 120.0,
                                seed: int = 0, hi: float = 1e4) -> float:
    """Smallest deadline achieving `target` attainment (bisection)."""
    lo = 0.0
    hi0 = hi
    if simulate(replicas, rate, hi0, duration=duration, seed=seed) < target:
        return float("inf")
    for _ in range(40):
        mid = (lo + hi) / 2
        if simulate(replicas, rate, mid, duration=duration, seed=seed) >= target:
            hi = mid
        else:
            lo = mid
    return hi


def peak_rate_for_attainment(replicas: Sequence[ReplicaModel],
                             deadline: float, target: float = 0.99, *,
                             duration: float = 120.0, seed: int = 0,
                             hi: float = 64.0) -> float:
    """Largest request rate sustaining `target` attainment (bisection)."""
    if simulate(replicas, 1e-3, deadline, duration=duration, seed=seed) < target:
        return 0.0
    lo = 1e-3
    while simulate(replicas, hi, deadline, duration=duration, seed=seed) >= target:
        hi *= 2
        if hi > 1e5:
            return hi
    for _ in range(30):
        mid = (lo + hi) / 2
        if simulate(replicas, mid, deadline, duration=duration, seed=seed) >= target:
            lo = mid
        else:
            hi = mid
    return lo
