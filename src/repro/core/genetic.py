"""§4.3: genetic search over partitions of the device pool into independent
pipeline groups.

Individual = tuple of disjoint device-id frozensets (groups). Each group is
layed out by the Algorithm-1 DP (dp_layout.optimize_pipeline); fitness is the
simulated SLO attainment of the resulting replica set (slo_sim), tie-broken
by mean latency.

Initialization: K-means over the latency-matrix embedding with the elbow
method choosing K (plus machine-per-group and whole-pool seeds). Mutations:
merge / split / swap, with early memory-feasibility pruning of offspring.
A `random` mutation mode reproduces the paper's strawman baseline (Fig. 6).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.cluster import Cluster
from repro.core.dp_layout import optimize_pipeline
from repro.core.plan import Assignment, DeploymentPlan, PipelinePlan

Individual = Tuple[FrozenSet[int], ...]


def _canon(groups: Sequence[FrozenSet[int]]) -> Individual:
    return tuple(sorted((g for g in groups if g), key=lambda g: sorted(g)))


# ---------------------------------------------------------------------------
# Initialization: K-means over comm topology + elbow
# ---------------------------------------------------------------------------

def _kmeans(feats: np.ndarray, k: int, rng: np.random.Generator,
            iters: int = 20) -> np.ndarray:
    n = len(feats)
    centers = feats[rng.choice(n, size=min(k, n), replace=False)]
    assign = np.zeros(n, dtype=int)
    for _ in range(iters):
        d = ((feats[:, None, :] - centers[None]) ** 2).sum(-1)
        assign = d.argmin(1)
        for c in range(len(centers)):
            pts = feats[assign == c]
            if len(pts):
                centers[c] = pts.mean(0)
    return assign


def _inertia(feats: np.ndarray, assign: np.ndarray) -> float:
    tot = 0.0
    for c in np.unique(assign):
        pts = feats[assign == c]
        tot += ((pts - pts.mean(0)) ** 2).sum()
    return tot


def kmeans_init(cluster: Cluster, rng: np.random.Generator,
                max_k: int = 12) -> List[Individual]:
    """Elbow-method K-means over the latency matrix rows (footnote: avoids
    slow cross-region links inside one group)."""
    feats = np.log10(cluster.lat + 1e-7)
    ks = range(1, min(max_k, len(cluster)) + 1)
    assigns, inertias = {}, []
    for k in ks:
        a = _kmeans(feats, k, rng)
        assigns[k] = a
        inertias.append(_inertia(feats, a))
    # elbow: max second difference
    if len(inertias) >= 3:
        d2 = np.diff(inertias, 2)
        k_star = int(np.argmax(d2)) + 2
    else:
        k_star = 1
    seeds = []
    for k in {k_star, max(1, k_star - 1), min(len(ks), k_star + 1)}:
        a = assigns[k]
        groups = [frozenset(np.flatnonzero(a == c).tolist())
                  for c in np.unique(a)]
        seeds.append(_canon(groups))
    # machine-per-group seed
    seeds.append(_canon([frozenset(ids) for ids in
                         cluster.machines().values()]))
    # whole pool
    seeds.append(_canon([frozenset(range(len(cluster)))]))
    return list(dict.fromkeys(seeds))


# ---------------------------------------------------------------------------
# Mutations (§4.3)
# ---------------------------------------------------------------------------

def mutate(ind: Individual, rng: np.random.Generator) -> Individual:
    groups = [set(g) for g in ind]
    op = rng.choice(["merge", "split", "swap"])
    if op == "merge" and len(groups) >= 2:
        i, j = rng.choice(len(groups), size=2, replace=False)
        groups[i] |= groups[j]
        del groups[j]
    elif op == "split" and groups:
        i = int(rng.integers(len(groups)))
        g = sorted(groups[i])
        if len(g) >= 2:
            # even split per the tau-vector definition
            a, b = set(g[0::2]), set(g[1::2])
            groups[i] = a
            groups.append(b)
    elif op == "swap" and len(groups) >= 2:
        i, j = rng.choice(len(groups), size=2, replace=False)
        if groups[i]:
            d = int(rng.choice(sorted(groups[i])))
            groups[i].discard(d)
            groups[j].add(d)
    return _canon([frozenset(g) for g in groups])


def mutate_random(ind: Individual, rng: np.random.Generator) -> Individual:
    """Strawman baseline: randomly reassign a few devices between groups."""
    groups = [set(g) for g in ind]
    if not groups:
        return ind
    for _ in range(int(rng.integers(1, 4))):
        all_devs = [d for g in groups for d in g]
        d = int(rng.choice(all_devs))
        for g in groups:
            g.discard(d)
        k = int(rng.integers(len(groups) + 1))
        if k == len(groups):
            groups.append({d})
        else:
            groups[k].add(d)
    return _canon([frozenset(g) for g in groups])


# ---------------------------------------------------------------------------
# Fitness
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    """What the search found: a DeploymentPlan plus search telemetry.

    The per-replica decisions (disaggregated role, speculation depth, KV
    pool precision, host-tier blocks) live on ``plan.replicas`` — one
    ``ReplicaSpec`` each — instead of the parallel Optional lists earlier
    releases carried. The old field names (``roles``, ``spec_ks``,
    ``kv_dtypes``, ``host_blocks``) remain as deprecated properties with
    identical semantics (None when the search ran without that
    dimension) for one release; new code should read ``result.plan``.
    """

    plan: DeploymentPlan
    attainment: float
    history: List[Tuple[float, float]]    # (wall_seconds, best_attainment)
    evaluations: int

    @property
    def assignment(self) -> Assignment:
        return self.plan.assignment

    @staticmethod
    def _deprecated(name: str) -> None:
        warnings.warn(
            f"SearchResult.{name} is deprecated; read the per-replica "
            f"values from SearchResult.plan.replicas (or the "
            f"DeploymentPlan.{name} view) instead",
            DeprecationWarning, stacklevel=3)

    @property
    def roles(self) -> Optional[List[str]]:
        self._deprecated("roles")
        return self.plan.roles

    @property
    def spec_ks(self) -> Optional[List[int]]:
        self._deprecated("spec_ks")
        return self.plan.spec_ks

    @property
    def kv_dtypes(self) -> Optional[List[Optional[str]]]:
        self._deprecated("kv_dtypes")
        return self.plan.kv_dtypes

    @property
    def host_blocks(self) -> Optional[List[int]]:
        self._deprecated("host_blocks")
        return self.plan.host_blocks


def choose_kv_dtypes(plans: Sequence[PipelinePlan],
                     capacity_at, *, rate: float
                     ) -> List[Optional[str]]:
    """The precision dimension of the search: per replica, keep the pool
    at model precision unless its KV capacity cannot hold its share of
    the in-flight demand, in which case quantize to int8 pages (~2-4x
    the sequences in the same memory, cost_model.kv_dtype_bytes_per_el).

    ``capacity_at(plan, kv_dtype)`` returns the replica's concurrent-
    sequence bound at a candidate precision. Demand is Little's law:
    rate/N arrivals/s held for the replica's end-to-end latency each.
    Quantization costs accuracy (bounded, but nonzero), so a replica
    that FITS at full precision stays there — only the memory-bound
    ones trade precision for capacity."""
    n = max(len(plans), 1)
    out: List[Optional[str]] = []
    for p in plans:
        need = rate / n * p.cost
        cap = capacity_at(p, None)
        out.append(None if cap >= need else "int8")
    return out


def choose_host_tiers(plans: Sequence[PipelinePlan], capacity_at, *,
                      rate: float, blocks_per_seq: int,
                      budget_blocks: int) -> List[int]:
    """The host-tier dimension of the search: split a pool-wide host-page
    budget across replicas proportionally to their device KV-capacity
    DEFICIT, so the small-HBM replicas — the ones whose device pools run
    dry and demote hardest — get the large host pools.

    ``capacity_at(plan)`` is the replica's device-tier concurrent-sequence
    bound; its Little's-law demand is rate/N arrivals/s held for the
    replica's end-to-end latency each. The shortfall, times
    ``blocks_per_seq``, is the replica's host demand in blocks. A pool
    with no deficit anywhere still churns prefixes under eviction, so an
    all-feasible replica set splits the budget evenly instead of
    discarding it."""
    n = len(plans)
    if n == 0 or budget_blocks <= 0:
        return [0] * n
    deficits = []
    for p in plans:
        need = rate / n * p.cost
        cap = capacity_at(p)
        deficits.append(max(0.0, need - cap) * max(blocks_per_seq, 1))
    total = sum(deficits)
    if total <= 0:
        base, extra = divmod(budget_blocks, n)
        return [base + (1 if i < extra else 0) for i in range(n)]
    return [int(budget_blocks * d / total) for d in deficits]


def choose_spec_ks(models: Sequence[slo_sim.PhasedReplicaModel], *,
                   alpha: float, draft_step_cost: float, s_out: int,
                   max_k: int = 8) -> Tuple[List[int], List[float]]:
    """The acceptance-aware speculation dimension: per replica, pick the
    depth k minimizing decode time per COMMITTED token
    (cost_model.best_spec_k) and return (ks, decode multipliers).

    A replica's decode STEP time is its decode bottleneck per generated
    token; the draft cost is absolute, so SLOW replicas amortize each
    draft over a bigger saved step and speculate DEEPER — exactly the
    heterogeneity lever: the laggard stage that paces the whole pool is
    the one multi-token commits help most. The multipliers feed
    ``PhasedReplicaModel.with_spec`` so the SLO simulator's workers
    consume decode in multi-token commits."""
    ks: List[int] = []
    mults: List[float] = []
    for m in models:
        step = m.decode_bottleneck / max(s_out, 1)
        if step <= 0.0:
            ks.append(0)
            mults.append(1.0)
            continue
        k = cm.best_spec_k(step, draft_step_cost, alpha, max_k=max_k)
        ks.append(k)
        mults.append(cm.spec_step_cost(step, draft_step_cost, alpha, k)
                     / step)
    return ks, mults


def best_role_split(models: Sequence[slo_sim.PhasedReplicaModel], *,
                    rate: float, deadline: float, kv_bytes: float = 0.0,
                    link_bw: float = float("inf"), link_lat: float = 0.0,
                    delay_fn=None, duration: float = 60.0, seed: int = 0
                    ) -> Tuple[Optional[List[str]], float]:
    """The disaggregation search dimension: split N replicas into prefill
    and decode roles, scored by the SLO simulator.

    Candidate prefill replicas are taken in order of comparative
    advantage (smallest prefill/decode bottleneck ratio first — the
    compute-rich replicas); every prefill-count k in [1, N) is simulated
    and the best attainment wins. Ties keep the SMALLEST k: decode
    replicas hold KV for a request's whole lifetime, so spare capacity
    belongs on the decode side. Returns (roles, attainment); (None, 0.0)
    when fewer than two replicas exist."""
    n = len(models)
    if n < 2:
        return None, 0.0
    order = sorted(range(n), key=lambda i: (
        models[i].prefill_bottleneck
        / max(models[i].decode_bottleneck, 1e-12), i))
    best_roles: Optional[List[str]] = None
    best_att = -1.0
    for k in range(1, n):
        pre = set(order[:k])
        roles = ["prefill" if i in pre else "decode" for i in range(n)]
        att = slo_sim.simulate_disagg(
            models, roles, rate, deadline, kv_bytes=kv_bytes,
            link_bw=link_bw, link_lat=link_lat, delay_fn=delay_fn,
            duration=duration, seed=seed)
        if att > best_att:
            best_roles, best_att = roles, att
    return best_roles, best_att


class Evaluator:
    def __init__(self, cluster: Cluster, model: cm.ModelProfile,
                 task: cm.Task, *, deadline: float, rate: float,
                 sim_duration: float = 60.0, seed: int = 0,
                 max_stages: int = 8, kv_block_size: Optional[int] = None,
                 prefix_hit_rate: float = 0.0,
                 disaggregate: bool = False, kv_link_gbps: float = 0.0,
                 spec_decode: bool = False, spec_alpha: float = 0.7,
                 spec_draft_cost: float = 0.0, max_spec_k: int = 8,
                 kv_dtype: Optional[str] = None,
                 kv_dtype_search: bool = False,
                 host_tier_bytes: float = 0.0,
                 host_swap_gbps: float = 0.0,
                 prefix_working_set: int = 0,
                 cluster_prefix: bool = False):
        self.cluster = cluster
        self.model = model
        self.task = task
        self.deadline = deadline
        self.rate = rate
        self.sim_duration = sim_duration
        self.seed = seed
        self.max_stages = max_stages
        # None -> idealized unbounded replicas (the paper's sim); an int
        # bounds each replica's in-flight requests by its KV capacity at
        # that block granularity (0 = contiguous rows), so paged capacity
        # shows up in simulated attainment. prefix_hit_rate further
        # deduplicates the planned per-sequence KV demand (shared prompt
        # blocks are resident once, serving.block_manager.PrefixIndex).
        self.kv_block_size = kv_block_size
        self.prefix_hit_rate = prefix_hit_rate
        # disaggregated serving: score each individual colocated AND under
        # its best prefill/decode role split (best_role_split); the KV
        # transfer is kv_bytes over a flat kv_link_gbps link, or over the
        # cluster's per-pair best links when kv_link_gbps <= 0
        self.disaggregate = disaggregate
        self.kv_link_gbps = kv_link_gbps
        # acceptance-aware speculative decoding: score each replica with
        # its best per-replica speculation depth (choose_spec_ks) at the
        # expected acceptance rate spec_alpha, charging spec_draft_cost
        # seconds per draft step
        self.spec_decode = spec_decode
        self.spec_alpha = spec_alpha
        self.spec_draft_cost = spec_draft_cost
        self.max_spec_k = max_spec_k
        # quantized KV pages: kv_dtype fixes ONE pool precision for every
        # replica (None = model default); kv_dtype_search instead picks
        # precision PER REPLICA (choose_kv_dtypes) — memory-bound replicas
        # quantize, the rest stay at model precision
        self.kv_dtype = kv_dtype
        self.kv_dtype_search = kv_dtype_search
        # host page tier + cluster prefix directory: host_tier_bytes is a
        # POOL-WIDE host-memory budget split across replicas by KV-capacity
        # deficit (choose_host_tiers -> SearchResult.host_blocks);
        # host_swap_gbps prices the swap/fetch link (<= 0 = free), and
        # prefix_working_set (tokens of hot shared prefixes) turns the
        # static prefix_hit_rate scalar into a residency-derived
        # ACHIEVABLE rate (cost_model.effective_prefix_hit_rate).
        # cluster_prefix lets every replica reach the others' resident
        # blocks through the shared directory (serving.cluster_kv).
        self.host_tier_bytes = host_tier_bytes
        self.host_swap_gbps = host_swap_gbps
        self.prefix_working_set = prefix_working_set
        self.cluster_prefix = cluster_prefix
        self._plan_cache: Dict[FrozenSet[int], Optional[PipelinePlan]] = {}
        self._fit_cache: Dict[Individual, Tuple[float, float]] = {}
        self._roles_cache: Dict[Individual, Optional[List[str]]] = {}
        self._spec_cache: Dict[Individual, Optional[List[int]]] = {}
        self._kvd_cache: Dict[Individual,
                              Optional[List[Optional[str]]]] = {}
        self._host_cache: Dict[Individual, Optional[List[int]]] = {}
        self.evaluations = 0

    def _feasible(self, group: FrozenSet[int]) -> bool:
        """Early check (§4.3): group memory must hold one model copy."""
        total = sum(self.cluster.devices[d].spec.mem_bytes for d in group)
        need = self.model.params_per_layer * self.model.num_layers \
            * self.task.bytes_per_el
        return total >= need

    def plan(self, group: FrozenSet[int]) -> Optional[PipelinePlan]:
        if group not in self._plan_cache:
            if not self._feasible(group):
                self._plan_cache[group] = None
            else:
                self._plan_cache[group] = optimize_pipeline(
                    self.cluster, sorted(group), self.model, self.task,
                    max_stages=self.max_stages)
        return self._plan_cache[group]

    def assignment(self, ind: Individual) -> Assignment:
        plans = [self.plan(g) for g in ind]
        return Assignment([p for p in plans if p is not None])

    def _max_concurrent(self, plan: PipelinePlan,
                        kv_dtype: Optional[str] = "__default__",
                        hit_rate: Optional[float] = None) -> int:
        """KV-capacity bound of one replica: the tightest stage's
        concurrent-sequence count at the configured block granularity
        (0 when capacity is idealized as unbounded) and pool precision
        (the evaluator-wide kv_dtype unless overridden per replica).
        ``hit_rate`` overrides the static prefix_hit_rate scalar with the
        residency-derived per-replica rate."""
        if self.kv_block_size is None:
            return 0
        if kv_dtype == "__default__":
            kv_dtype = self.kv_dtype
        if hit_rate is None:
            hit_rate = self.prefix_hit_rate
        return min(cm.concurrent_capacity(
            self.cluster, st.device_ids, st.num_layers, self.model,
            self.task, block_size=self.kv_block_size,
            prefix_hit_rate=hit_rate, kv_dtype=kv_dtype)
            for st in plan.stages)

    def _phase_model(self, plan: PipelinePlan,
                     kv_dtype: Optional[str] = "__default__",
                     hit_rate: Optional[float] = None
                     ) -> slo_sim.PhasedReplicaModel:
        stages = [st.device_ids for st in plan.stages]
        pc = cm.pipeline_phase_costs(self.cluster, stages, plan.layer_split,
                                     self.model, self.task)
        return slo_sim.PhasedReplicaModel(
            prefill_latency=pc.prefill_latency,
            prefill_bottleneck=pc.prefill_bottleneck,
            decode_latency=pc.decode_latency,
            decode_bottleneck=pc.decode_bottleneck,
            max_concurrent=self._max_concurrent(plan, kv_dtype, hit_rate))

    def _replica_hit_rates(self, plans: Sequence[PipelinePlan],
                           host_blocks: Optional[List[int]],
                           kv_dtypes: Optional[List[Optional[str]]]
                           ) -> Optional[List[float]]:
        """Residency-derived per-replica prefix hit rates replacing the
        static scalar: each replica's reach is its device pool blocks +
        its host tier + (cluster_prefix) every peer's resident blocks,
        tier blocks discounted by swap-vs-recompute time. None when no
        working set was given (the static scalar stands)."""
        bs = self.kv_block_size
        if self.prefix_working_set <= 0 or not bs or not plans:
            return None
        ws = -(-self.prefix_working_set // bs)

        def kvd(i):
            return kv_dtypes[i] if kv_dtypes is not None else self.kv_dtype

        hb = host_blocks if host_blocks is not None else [0] * len(plans)
        dev, disc = [], []
        for i, p in enumerate(plans):
            dev.append(min(cm.device_pool_blocks(
                self.cluster, st.device_ids, st.num_layers, self.model,
                self.task, bs, kv_dtype=kvd(i)) for st in p.stages))
            if self.host_swap_gbps > 0:
                swap = cm.host_swap_seconds_per_block(
                    self.model, self.task, bs, self.host_swap_gbps,
                    kv_dtype=kvd(i))
                pc = cm.pipeline_phase_costs(
                    self.cluster, [st.device_ids for st in p.stages],
                    [st.num_layers for st in p.stages], self.model,
                    self.task)
                recompute = pc.prefill_latency / max(self.task.s_in, 1) * bs
                disc.append(min(1.0, swap / recompute)
                            if recompute > 0 else 1.0)
            else:
                disc.append(0.0)
        reach = [dev[i] + hb[i] for i in range(len(plans))]
        out = []
        for i in range(len(plans)):
            peers = sum(reach) - reach[i] if self.cluster_prefix else 0
            out.append(cm.effective_prefix_hit_rate(
                self.prefix_hit_rate, working_set_blocks=ws,
                device_blocks=dev[i], host_blocks=hb[i],
                peer_blocks=peers, tier_discount=disc[i]))
        return out

    def _pair_delay_fn(self, plans: List[PipelinePlan], kv_bytes: float):
        """Per-pair transfer delay over the cluster's best link from the
        source pipeline's LAST stage to the destination's FIRST."""
        def delay(i: int, j: int) -> float:
            best = min((float(self.cluster.lat[a, b])
                        + kv_bytes / float(self.cluster.bw[a, b]))
                       for a in plans[i].stages[-1].device_ids
                       for b in plans[j].stages[0].device_ids)
            return best
        return delay

    def roles_for(self, ind: Individual) -> Optional[List[str]]:
        """The role split fitness() chose for `ind` (None = colocated)."""
        self.fitness(ind)
        return self._roles_cache[ind]

    def spec_ks_for(self, ind: Individual) -> Optional[List[int]]:
        """The per-replica speculation depths fitness() chose for `ind`
        (None = search ran without spec_decode)."""
        self.fitness(ind)
        return self._spec_cache[ind]

    def kv_dtypes_for(self, ind: Individual
                      ) -> Optional[List[Optional[str]]]:
        """The per-replica pool precisions fitness() chose for `ind`
        (None = search ran without kv_dtype_search)."""
        self.fitness(ind)
        return self._kvd_cache[ind]

    def host_blocks_for(self, ind: Individual) -> Optional[List[int]]:
        """The per-replica host-tier capacities (blocks) fitness() chose
        for `ind` (None = search ran without host_tier_bytes)."""
        self.fitness(ind)
        return self._host_cache[ind]

    def fitness(self, ind: Individual) -> Tuple[float, float]:
        """(SLO attainment, -mean latency) to maximize lexicographically.
        With disaggregate=True the attainment is the better of colocated
        serving and the best prefill/decode role split; with
        spec_decode=True every replica is scored at its acceptance-aware
        best speculation depth (multi-token decode commits)."""
        if ind in self._fit_cache:
            return self._fit_cache[ind]
        self.evaluations += 1
        asg = self.assignment(ind)
        # precision per replica: memory-bound replicas quantize to int8
        # pages, the rest keep the model default (choose_kv_dtypes)
        kv_dtypes = None
        if self.kv_dtype_search and self.kv_block_size is not None \
                and asg.pipelines:
            kv_dtypes = choose_kv_dtypes(
                asg.pipelines,
                lambda p, kvd: self._max_concurrent(p, kvd),
                rate=self.rate)

        def kvd(i: int) -> Optional[str]:
            return kv_dtypes[i] if kv_dtypes is not None else self.kv_dtype

        # host tier: split the pool-wide host budget by device-capacity
        # deficit (small-HBM replicas get the big pools), then derive the
        # per-replica ACHIEVABLE prefix hit rate from total residency
        host_blocks = None
        if self.host_tier_bytes > 0 and self.kv_block_size \
                and asg.pipelines:
            budget = cm.host_tier_blocks(
                self.host_tier_bytes, self.model, self.task,
                self.kv_block_size, kv_dtype=self.kv_dtype)
            bps = -(-(self.task.s_in + self.task.s_out)
                    // self.kv_block_size)
            host_blocks = choose_host_tiers(
                asg.pipelines,
                lambda p: self._max_concurrent(p),
                rate=self.rate, blocks_per_seq=bps, budget_blocks=budget)
        hit_rates = self._replica_hit_rates(asg.pipelines, host_blocks,
                                            kv_dtypes)

        def hr(i: int) -> Optional[float]:
            return hit_rates[i] if hit_rates is not None else None

        models = None
        spec_ks = None
        if (self.spec_decode or self.disaggregate) and asg.pipelines:
            models = [self._phase_model(p, kvd(i), hr(i))
                      for i, p in enumerate(asg.pipelines)]
        if self.spec_decode and models:
            spec_ks, mults = choose_spec_ks(
                models, alpha=self.spec_alpha,
                draft_step_cost=self.spec_draft_cost,
                s_out=self.task.s_out, max_k=self.max_spec_k)
            models = [m.with_spec(u) for m, u in zip(models, mults)]
            # colocated scoring through the phase-split model so the
            # multiplier shaves exactly the decode share of the cost
            reps = [m.colocated() for m in models]
        else:
            reps = [slo_sim.ReplicaModel(
                p.cost, p.bottleneck,
                max_concurrent=self._max_concurrent(p, kvd(i), hr(i)))
                for i, p in enumerate(asg.pipelines)]
        att = slo_sim.simulate(reps, self.rate, self.deadline,
                               duration=self.sim_duration, seed=self.seed)
        roles = None
        if self.disaggregate and len(asg.pipelines) >= 2:
            # migration ships the CACHE dtype over the link; with per-
            # replica search the wire runs at the quantized width as soon
            # as any replica quantized (the serving layer coerces one
            # uniform pool dtype across a disaggregated group)
            wire_kvd = self.kv_dtype
            if kv_dtypes is not None and any(kv_dtypes):
                wire_kvd = next(d for d in kv_dtypes if d)
            kv_bytes = cm.kv_migration_bytes(self.model, self.task,
                                             self.kv_block_size or 0,
                                             kv_dtype=wire_kvd)
            if self.kv_link_gbps > 0:
                kw = dict(kv_bytes=kv_bytes,
                          link_bw=self.kv_link_gbps * 1e9 / 8)
            else:
                kw = dict(delay_fn=self._pair_delay_fn(asg.pipelines,
                                                       kv_bytes))
            d_roles, d_att = best_role_split(
                models, rate=self.rate, deadline=self.deadline,
                duration=self.sim_duration, seed=self.seed, **kw)
            if d_roles is not None and d_att > att:
                att, roles = d_att, d_roles
        self._roles_cache[ind] = roles
        self._spec_cache[ind] = spec_ks
        self._kvd_cache[ind] = kv_dtypes
        self._host_cache[ind] = host_blocks
        mean_lat = np.mean([p.cost for p in asg.pipelines]) if asg.pipelines \
            else float("inf")
        out = (att, -mean_lat)
        self._fit_cache[ind] = out
        return out


def search(cluster: Cluster, model: cm.ModelProfile, task: cm.Task, *,
           deadline: float, rate: float, iters: int = 60,
           pop_size: int = 10, seed: int = 0, mutation: str = "hexgen",
           sim_duration: float = 60.0, max_stages: int = 8,
           kv_block_size: Optional[int] = None,
           prefix_hit_rate: float = 0.0,
           disaggregate: bool = False, kv_link_gbps: float = 0.0,
           spec_decode: bool = False, spec_alpha: float = 0.7,
           spec_draft_cost: float = 0.0, max_spec_k: int = 8,
           kv_dtype: Optional[str] = None, kv_dtype_search: bool = False,
           host_tier_bytes: float = 0.0, host_swap_gbps: float = 0.0,
           prefix_working_set: int = 0, cluster_prefix: bool = False,
           init: Optional[List[Individual]] = None) -> SearchResult:
    """The full two-phase search: genetic over partitions, DP inside.
    disaggregate=True adds the prefill/decode role split as a scored
    search dimension (SearchResult.roles); spec_decode=True scores every
    replica at its acceptance-aware best speculation depth
    (SearchResult.spec_ks — slow replicas speculate deeper);
    kv_dtype fixes one pool precision for every replica, while
    kv_dtype_search=True picks precision PER REPLICA instead
    (SearchResult.kv_dtypes — memory-bound replicas quantize).

    host_tier_bytes > 0 adds the HOST PAGE TIER dimension: the pool-wide
    host budget is split across replicas by device KV-capacity deficit
    (SearchResult.host_blocks — small-HBM replicas get the big pools),
    with swaps priced at host_swap_gbps. prefix_working_set (tokens of
    hot shared prefixes) replaces the static prefix_hit_rate scalar with
    a residency-derived achievable rate per replica; cluster_prefix=True
    counts peer-resident blocks behind the shared directory toward each
    replica's reach (serving.cluster_kv)."""
    rng = np.random.default_rng(seed)
    ev = Evaluator(cluster, model, task, deadline=deadline, rate=rate,
                   sim_duration=sim_duration, seed=seed,
                   max_stages=max_stages, kv_block_size=kv_block_size,
                   prefix_hit_rate=prefix_hit_rate,
                   disaggregate=disaggregate, kv_link_gbps=kv_link_gbps,
                   spec_decode=spec_decode, spec_alpha=spec_alpha,
                   spec_draft_cost=spec_draft_cost, max_spec_k=max_spec_k,
                   kv_dtype=kv_dtype, kv_dtype_search=kv_dtype_search,
                   host_tier_bytes=host_tier_bytes,
                   host_swap_gbps=host_swap_gbps,
                   prefix_working_set=prefix_working_set,
                   cluster_prefix=cluster_prefix)
    if init is None:
        if mutation == "hexgen":
            pop = kmeans_init(cluster, rng)
        else:
            # strawman: random partitions
            pop = []
            for _ in range(4):
                k = int(rng.integers(1, max(2, len(cluster) // 4)))
                a = rng.integers(0, k, size=len(cluster))
                pop.append(_canon([frozenset(np.flatnonzero(a == c).tolist())
                                   for c in range(k)]))
    else:
        pop = list(init)
    mut = mutate if mutation == "hexgen" else mutate_random

    # offline scheduler-search profiling, not serving-path time: the
    # anytime-curve `history` records real search wall time by design
    t0 = time.monotonic()             # repro: noqa[clock-discipline]
    scored = sorted(((ev.fitness(i), i) for i in pop), reverse=True)
    history = [(time.monotonic() - t0, scored[0][0][0])]  # repro: noqa[clock-discipline]
    for _ in range(iters):
        # sample parents biased to the best
        parents = [i for _, i in scored[:max(2, pop_size // 2)]]
        children = []
        for p in parents:
            child = mut(p, rng)
            if mutation == "hexgen":
                # early feasibility pruning of offspring groups
                if not any(ev._feasible(g) for g in child):
                    continue
            children.append(child)
        allc = {i for _, i in scored} | set(children)
        scored = sorted(((ev.fitness(i), i) for i in allc), reverse=True)
        scored = scored[:pop_size]
        history.append((time.monotonic() - t0, scored[0][0][0]))  # repro: noqa[clock-discipline]
    best = scored[0][1]
    asg = ev.assignment(best)
    plan = DeploymentPlan.from_search(asg, roles=ev.roles_for(best),
                                      spec_ks=ev.spec_ks_for(best),
                                      kv_dtypes=ev.kv_dtypes_for(best),
                                      host_blocks=ev.host_blocks_for(best))
    return SearchResult(plan=plan, attainment=scored[0][0][0],
                        history=history, evaluations=ev.evaluations)
