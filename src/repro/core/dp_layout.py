"""Algorithm 1: dynamic programming over (stage, type-vector) to find the
optimal per-stage GPU allocation of ONE pipeline, given a layer partition.

Faithful to the paper with one refinement (documented in DESIGN.md): the
paper's DP state tracks GPU-*type* counts and relies on the heuristic that a
TP group uses one type on one machine; we track per-*machine* counts (a
machine's GPUs are one type, and machines are what the comm matrices
distinguish), and extend the state with the previous stage's machine so the
PP link cost is exact rather than estimated.

The EM heuristic from §4.3 ("Determine the pipeline partitions") is also
here: even split -> DP -> layers proportional to assigned stage memory -> DP.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import cost_model as cm
from repro.core.cluster import Cluster
from repro.core.plan import PipelinePlan, StagePlan

TP_CANDIDATES = (1, 2, 4, 8)


def _pools(cluster: Cluster, device_ids: Sequence[int]) -> Dict[int, List[int]]:
    """machine -> device ids available in this pipeline group."""
    pools: Dict[int, List[int]] = {}
    for d in sorted(device_ids):
        pools.setdefault(cluster.devices[d].machine, []).append(d)
    return pools


def dp_assign(cluster: Cluster, device_ids: Sequence[int],
              layer_split: Sequence[int], model: cm.ModelProfile,
              task: cm.Task,
              tp_candidates: Sequence[int] = TP_CANDIDATES
              ) -> Optional[List[List[int]]]:
    """Returns per-stage device-id lists minimizing Eq. 2, or None."""
    pools = _pools(cluster, device_ids)
    machines = sorted(pools)
    S = len(layer_split)

    # devices within a machine are interchangeable -> memoize stage terms
    @functools.lru_cache(maxsize=None)
    def stage_cost(mi: int, tp: int, l: int) -> float:
        devs = pools[machines[mi]][:tp]
        if not cm.mem_ok(cluster, devs, l, model, task):
            return float("inf")
        return cm.comp_cost(cluster, devs, l, model, task) \
            + cm.comm_tp_cost(cluster, devs, l, model, task)

    @functools.lru_cache(maxsize=None)
    def pp_cost(prev_mi: int, mi: int) -> float:
        prev_dev = [pools[machines[prev_mi]][0]]
        devs = [pools[machines[mi]][0]]
        return cm.comm_pp_cost(cluster, prev_dev, devs, task, model)

    @functools.lru_cache(maxsize=None)
    def best(j: int, used: Tuple[int, ...], prev_m: int
             ) -> Tuple[float, Optional[Tuple[int, int]]]:
        """Min cost of stages j.. given `used` counts; returns (cost, choice)
        where choice = (machine_index, tp)."""
        if j == S:
            return 0.0, None
        out = (float("inf"), None)
        for mi, m in enumerate(machines):
            avail = len(pools[m]) - used[mi]
            for tp in tp_candidates:
                if tp > avail:
                    continue
                c = stage_cost(mi, tp, layer_split[j])
                if c == float("inf"):
                    continue
                if prev_m >= 0:
                    c += pp_cost(prev_m, mi)
                used2 = tuple(u + (tp if i == mi else 0)
                              for i, u in enumerate(used))
                rest, _ = best(j + 1, used2, mi)
                if c + rest < out[0]:
                    out = (c + rest, (mi, tp))
        return out

    cost, _ = best(0, tuple(0 for _ in machines), -1)
    if cost == float("inf"):
        return None

    # back-track
    stages: List[List[int]] = []
    used = tuple(0 for _ in machines)
    prev_m = -1
    for j in range(S):
        _, choice = best(j, used, prev_m)
        mi, tp = choice
        m = machines[mi]
        stages.append(pools[m][used[mi]:used[mi] + tp])
        used = tuple(u + (tp if i == mi else 0) for i, u in enumerate(used))
        prev_m = mi
    return stages


def _even_split(L: int, S: int) -> List[int]:
    base = L // S
    rem = L % S
    return [base + (1 if j < rem else 0) for j in range(S)]


def _mem_proportional_split(cluster: Cluster, stages: List[List[int]],
                            L: int) -> List[int]:
    caps = [sum(cluster.devices[d].spec.mem_bytes for d in devs)
            for devs in stages]
    tot = sum(caps)
    raw = [c / tot * L for c in caps]
    split = [max(1, int(round(r))) for r in raw]
    # fix rounding to sum exactly to L
    while sum(split) > L:
        i = max(range(len(split)), key=lambda i: split[i] - raw[i])
        if split[i] > 1:
            split[i] -= 1
        else:
            break
    while sum(split) < L:
        i = min(range(len(split)), key=lambda i: split[i] - raw[i])
        split[i] += 1
    return split


# DP state-space guard: above this, fall back to the greedy machine-per-stage
# layout (giant merged groups appear transiently during genetic search and
# are rarely competitive; the exact DP still covers every realistic group).
MAX_DP_STATES = 300_000


def _greedy_layout(cluster: Cluster, device_ids: Sequence[int],
                   model: cm.ModelProfile, task: cm.Task
                   ) -> Optional[PipelinePlan]:
    """Each machine = one stage (TP = machine size), layers ∝ memory."""
    pools = _pools(cluster, device_ids)
    stages = [devs for _, devs in sorted(pools.items())]
    L = model.num_layers
    split = _mem_proportional_split(cluster, stages, L)
    cost = cm.pipeline_cost(cluster, stages, split, model, task)
    if cost == float("inf"):
        return None
    bott = cm.pipeline_bottleneck(cluster, stages, split, model, task)
    return PipelinePlan(
        stages=[StagePlan(list(devs), l) for devs, l in zip(stages, split)],
        cost=cost, bottleneck=bott)


def optimize_pipeline(cluster: Cluster, device_ids: Sequence[int],
                      model: cm.ModelProfile, task: cm.Task, *,
                      max_stages: int = 8,
                      tp_candidates: Sequence[int] = TP_CANDIDATES,
                      em_iters: int = 2) -> Optional[PipelinePlan]:
    """Search stage count + EM layer partition + DP GPU assignment for one
    pipeline group. Returns the best PipelinePlan or None if infeasible."""
    L = model.num_layers
    best_plan: Optional[PipelinePlan] = None
    # quick feasibility: total memory must hold one model copy
    B = task.bytes_per_el
    total_mem = sum(cluster.devices[d].spec.mem_bytes for d in device_ids)
    if total_mem < model.params_per_layer * L * B:
        return None
    pools = _pools(cluster, device_ids)
    states = max_stages * len(pools)
    for devs in pools.values():
        states *= len(devs) + 1
    if states > MAX_DP_STATES:
        return _greedy_layout(cluster, device_ids, model, task)
    for S in range(1, min(max_stages, len(device_ids)) + 1):
        split = _even_split(L, S)
        stages = None
        for _ in range(em_iters):
            got = dp_assign(cluster, device_ids, split, model, task,
                            tp_candidates)
            if got is None:
                break
            stages = got
            new_split = _mem_proportional_split(cluster, stages, L)
            if new_split == split:
                break
            split = new_split
        if stages is None:
            continue
        cost = cm.pipeline_cost(cluster, stages, split, model, task)
        if cost == float("inf"):
            continue
        bott = cm.pipeline_bottleneck(cluster, stages, split, model, task)
        plan = PipelinePlan(
            stages=[StagePlan(list(devs), l) for devs, l in zip(stages, split)],
            cost=cost, bottleneck=bott)
        if best_plan is None or plan.cost < best_plan.cost:
            best_plan = plan
    return best_plan
