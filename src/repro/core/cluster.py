"""Heterogeneous device pool descriptors: GPU/TPU catalog, machines, regions,
and the alpha-beta communication matrices (paper §4.1: A = latency, B =
bandwidth).

The paper's evaluation environments are reproduced verbatim:
  - homogeneous:        2 x p4d.24xlarge (8 x A100-40G each), $65.54/h
  - hetero full-price:  58 GPUs across Iceland/Norway/Nevada/Illinois, $65.04/h
  - hetero half-price:  30 GPUs across Iceland/Norway/Nevada, $29.6/h
  - case study (§3.1):  4xA6000 + 2xA5000 + 2xA4000

Network constants follow the paper's footnote 3: intra-region 2 ms / 5 Gbps,
inter-region 40-150 ms / 0.3-1.0 Gbps; intra-machine NVLink (A100) or PCIe.

A TPU v5e entry is included so the same scheduler can plan over mixed pod
slices (the TPU-native analogue of a heterogeneous pool — see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str
    mem_bytes: float          # M_d
    mem_bw: float             # m_d, bytes/s
    flops: float              # c_d, FLOP/s (fp16/bf16 tensor)
    price_per_hour: float
    intra_machine_bw: float   # bytes/s between peers on the same machine
    intra_machine_lat: float  # seconds


GPU_CATALOG: Dict[str, GPUSpec] = {
    # name                mem          mem_bw      flops      $/h    intra bw    lat
    "A100-40G": GPUSpec("A100-40G", 40 * GB, 1555e9, 312e12, 4.10, 600e9 / 2, 5e-6),
    "3090Ti":   GPUSpec("3090Ti",   24 * GB, 1008e9, 160e12, 1.10, 25e9,      1e-5),
    "A6000":    GPUSpec("A6000",    48 * GB,  768e9, 155e12, 1.35, 25e9,      1e-5),
    "A5000":    GPUSpec("A5000",    24 * GB,  768e9, 111e12, 1.00, 25e9,      1e-5),
    "A4000":    GPUSpec("A4000",    16 * GB,  448e9,  76e12, 0.60, 25e9,      1e-5),
    "A40":      GPUSpec("A40",      48 * GB,  696e9, 150e12, 1.30, 25e9,      1e-5),
    # TPU target (per-chip; ICI links, DESIGN.md §3)
    "TPUv5e":   GPUSpec("TPUv5e",   16 * GB,  819e9, 197e12, 1.20, 50e9,      1e-6),
}

INTRA_REGION_LAT, INTRA_REGION_BW = 2e-3, 5e9 / 8          # 2 ms, 5 Gbps
INTER_REGION_LAT, INTER_REGION_BW = 100e-3, 0.6e9 / 8      # mid-range of 40-150ms / .3-1Gbps


@dataclasses.dataclass(frozen=True)
class Device:
    id: int
    type: str                 # key into GPU_CATALOG
    machine: int
    region: str

    @property
    def spec(self) -> GPUSpec:
        return GPU_CATALOG[self.type]


class Cluster:
    """Device pool + comm matrices. A[i,j] latency (s), B[i,j] bandwidth (B/s)."""

    def __init__(self, devices: Sequence[Device],
                 lat: Optional[np.ndarray] = None,
                 bw: Optional[np.ndarray] = None):
        self.devices: List[Device] = list(devices)
        n = len(self.devices)
        if lat is None or bw is None:
            lat = np.zeros((n, n))
            bw = np.full((n, n), np.inf)
            for a, b in itertools.combinations(range(n), 2):
                da, db = self.devices[a], self.devices[b]
                if da.machine == db.machine:
                    l = max(da.spec.intra_machine_lat, db.spec.intra_machine_lat)
                    w = min(da.spec.intra_machine_bw, db.spec.intra_machine_bw)
                elif da.region == db.region:
                    l, w = INTRA_REGION_LAT, INTRA_REGION_BW
                else:
                    l, w = INTER_REGION_LAT, INTER_REGION_BW
                lat[a, b] = lat[b, a] = l
                bw[a, b] = bw[b, a] = w
        self.lat = lat
        self.bw = bw

    def __len__(self):
        return len(self.devices)

    @property
    def price_per_hour(self) -> float:
        return sum(d.spec.price_per_hour for d in self.devices)

    def machines(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for d in self.devices:
            out.setdefault(d.machine, []).append(d.id)
        return out

    def subset(self, ids: Sequence[int]) -> List[Device]:
        return [self.devices[i] for i in ids]


def _build(machines: List[Tuple[str, int, str]]) -> Cluster:
    """machines: list of (gpu_type, count, region)."""
    devices = []
    for m, (gtype, count, region) in enumerate(machines):
        for _ in range(count):
            devices.append(Device(len(devices), gtype, m, region))
    return Cluster(devices)


def homogeneous_a100() -> Cluster:
    """2 x AWS p4d.24xlarge."""
    return _build([("A100-40G", 8, "us-east"), ("A100-40G", 8, "us-east")])


def hetero_full_price() -> Cluster:
    """Paper §5.1: 58 GPUs, ~$65/h."""
    return _build([
        ("3090Ti", 8, "iceland"), ("3090Ti", 8, "iceland"),
        ("3090Ti", 3, "norway"), ("3090Ti", 3, "norway"),
        ("A5000", 8, "nevada"),
        ("A6000", 8, "illinois"), ("A6000", 8, "illinois"),
        ("A5000", 8, "illinois"),
        ("A40", 4, "illinois"),
    ])


def hetero_half_price() -> Cluster:
    """Paper §5.1: 30 GPUs, ~$29.6/h."""
    return _build([
        ("3090Ti", 8, "iceland"), ("3090Ti", 8, "iceland"),
        ("3090Ti", 3, "norway"), ("3090Ti", 3, "norway"),
        ("A5000", 8, "nevada"),
    ])


def case_study_cluster() -> Cluster:
    """Paper §3.1 case study: 4xA6000 + 2xA5000 + 2xA4000 (one region)."""
    return _build([
        ("A6000", 4, "region0"), ("A5000", 2, "region0"),
        ("A4000", 2, "region0"),
    ])


def tpu_mixed_slices() -> Cluster:
    """Beyond-paper: two v5e slices of different sizes joined over DCN."""
    return _build([("TPUv5e", 8, "zone-a"), ("TPUv5e", 4, "zone-a"),
                   ("TPUv5e", 4, "zone-b")])
