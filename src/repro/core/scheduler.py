"""Two-phase HexGen scheduler: public entry point (Contribution 2)."""
from __future__ import annotations

from typing import Optional

from repro.configs import get_config
from repro.core import cost_model as cm
from repro.core import genetic
from repro.core.cluster import Cluster
from repro.core.genetic import SearchResult


def schedule(cluster: Cluster, arch: str, task: cm.Task, *,
             deadline: float, rate: float, iters: int = 60,
             seed: int = 0, mutation: str = "hexgen",
             paper_exact: bool = False,
             max_stages: int = 8, kv_block_size=None,
             prefix_hit_rate: float = 0.0,
             disaggregate: bool = False,
             kv_link_gbps: float = 0.0,
             spec_decode: bool = False,
             spec_alpha: float = 0.7,
             spec_draft_cost: float = 0.0,
             max_spec_k: int = 8,
             kv_dtype: Optional[str] = None,
             kv_dtype_search: bool = False,
             host_tier_bytes: float = 0.0,
             host_swap_gbps: float = 0.0,
             prefix_working_set: int = 0,
             cluster_prefix: bool = False) -> SearchResult:
    """Find an assignment of `cluster` serving `arch` replicas.

    deadline: SLO latency bound (s); rate: request rate (req/s).
    mutation="random" reproduces the paper's strawman baseline.
    kv_block_size (None = idealized unbounded replicas) bounds each
    simulated replica's in-flight requests by its KV capacity at that
    paged-block granularity (0 = contiguous rows). prefix_hit_rate is the
    expected fraction of prompt tokens served from the prefix cache
    (serving prefix_caching=True): the capacity bound then plans against
    the effective, DEDUPLICATED per-sequence KV demand.

    disaggregate=True adds the prefill/decode ROLE SPLIT as a search
    dimension: every candidate replica set is also scored under its best
    role assignment (phase-split costs + the SLO simulator's phased
    workers), with the KV handoff modeled over a flat kv_link_gbps link
    (<= 0: the cluster's per-pair best links). The winning split lands in
    SearchResult.roles (None when colocated serving won), aligned with
    assignment.pipelines — pass it to InferenceEngine(roles=...).

    spec_decode=True makes the search ACCEPTANCE-AWARE: every replica is
    scored at its best per-replica speculation depth (cost per COMMITTED
    token given acceptance rate spec_alpha and an absolute
    spec_draft_cost per draft step — cost_model.best_spec_k), so slow
    replicas speculate deeper. The chosen depths land in
    SearchResult.spec_ks, aligned with assignment.pipelines — pass them
    to InferenceEngine(spec_ks=...).

    kv_dtype prices every replica's KV capacity (and the disaggregation
    wire) at that paged-pool storage precision ("int8"/"fp8" pages hold
    ~2-4x the sequences of fp32 in the same memory);
    kv_dtype_search=True instead picks precision PER REPLICA — only the
    memory-bound replicas quantize. The choices land in
    SearchResult.kv_dtypes, aligned with assignment.pipelines — pass
    them to InferenceEngine(kv_dtypes=...).

    host_tier_bytes > 0 sizes a HOST PAGE TIER under the device pools:
    the pool-wide host budget lands on the replicas with the largest
    device KV-capacity deficit (small-HBM GPUs get the big host pools),
    with swap-in/swap-out priced at host_swap_gbps Gbit/s. The per-
    replica capacities land in SearchResult.host_blocks — pass them to
    InferenceEngine(host_blocks=...). prefix_working_set (tokens of hot
    shared prefixes) replaces the static prefix_hit_rate scalar with the
    ACHIEVABLE per-replica rate derived from tiered residency
    (cost_model.effective_prefix_hit_rate); cluster_prefix=True counts
    peer-resident blocks behind the shared directory toward each
    replica's reach, matching serving cluster_prefix=True.
    """
    cfg = get_config(arch)
    profile = cm.ModelProfile.from_config(cfg, paper_exact=paper_exact,
                                          bytes_per_el=task.bytes_per_el)
    res = genetic.search(cluster, profile, task, deadline=deadline,
                         rate=rate, iters=iters, seed=seed,
                         mutation=mutation, max_stages=max_stages,
                         kv_block_size=kv_block_size,
                         prefix_hit_rate=prefix_hit_rate,
                         disaggregate=disaggregate,
                         kv_link_gbps=kv_link_gbps,
                         spec_decode=spec_decode, spec_alpha=spec_alpha,
                         spec_draft_cost=spec_draft_cost,
                         max_spec_k=max_spec_k, kv_dtype=kv_dtype,
                         kv_dtype_search=kv_dtype_search,
                         host_tier_bytes=host_tier_bytes,
                         host_swap_gbps=host_swap_gbps,
                         prefix_working_set=prefix_working_set,
                         cluster_prefix=cluster_prefix)
    res.assignment.validate(cfg.num_layers)
    return res
