"""Plan dataclasses: the output of the scheduler.

An Assignment maps the device pool onto independent inference pipelines
(model replicas); each pipeline is a list of stages; each stage owns a
disjoint GPU set (its tensor-parallel group) and a contiguous span of layers.
This mirrors the paper's sigma: D -> {(d_ij, l_ij)}.

A DeploymentPlan is the UNIFIED plan surface on top of that: one
ReplicaSpec per replica carrying the pipeline layout plus every per-replica
serving decision the search makes (disaggregated role, speculation depth,
KV pool precision, host-tier capacity). The online rescheduler diffs two
DeploymentPlans to compute the migrations that turn one into the other.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StagePlan:
    device_ids: List[int]          # the TP group (>=1 devices, same machine/type)
    num_layers: int                # l_ij

    @property
    def tp_degree(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass
class PipelinePlan:
    stages: List[StagePlan]
    cost: float = float("inf")     # end-to-end latency estimate (Eq. 2)
    bottleneck: float = 0.0        # max per-stage time (pipelining throughput)

    @property
    def device_ids(self) -> List[int]:
        return [d for s in self.stages for d in s.device_ids]

    @property
    def layer_split(self) -> List[int]:
        return [s.num_layers for s in self.stages]

    def describe(self) -> str:
        return "[" + ",".join(str(s.tp_degree) for s in self.stages) + "]" \
            + " layers=" + str(self.layer_split)


@dataclasses.dataclass
class Assignment:
    pipelines: List[PipelinePlan]

    def validate(self, total_layers: int) -> None:
        seen = set()
        for p in self.pipelines:
            assert sum(s.num_layers for s in p.stages) == total_layers, \
                (p.layer_split, total_layers)
            for d in p.device_ids:
                assert d not in seen, f"device {d} assigned twice"
                seen.add(d)

    @property
    def num_replicas(self) -> int:
        return len(self.pipelines)

    def describe(self) -> str:
        return "; ".join(p.describe() for p in self.pipelines)


# ---------------------------------------------------------------------------
# The unified plan surface
# ---------------------------------------------------------------------------

# the per-replica dimensions a search may (or may not) have decided; a
# DeploymentPlan records WHICH were searched so "dimension off" and
# "dimension chose the default" stay distinguishable
PLAN_DIMS = ("roles", "spec", "kv_dtype", "host_tier")


@dataclasses.dataclass
class ReplicaSpec:
    """One replica's complete serving contract: its pipeline layout plus
    every per-replica decision the scheduler made for it."""

    pipeline: PipelinePlan
    role: str = "both"             # "prefill" | "decode" | "both"
    spec_k: int = 0                # speculation depth (0 = plain decode)
    kv_dtype: Optional[str] = None  # pool precision (None = model default)
    host_blocks: int = 0           # host page tier capacity in blocks

    @property
    def device_ids(self) -> List[int]:
        return self.pipeline.device_ids

    @property
    def key(self) -> FrozenSet[int]:
        """Replica identity for plan diffing: the device set is disjoint
        across a valid plan, so it names the replica across re-solves."""
        return frozenset(self.pipeline.device_ids)

    def describe(self) -> str:
        bits = [self.pipeline.describe()]
        if self.role != "both":
            bits.append(self.role)
        if self.spec_k:
            bits.append(f"k={self.spec_k}")
        if self.kv_dtype:
            bits.append(self.kv_dtype)
        if self.host_blocks:
            bits.append(f"host={self.host_blocks}")
        return " ".join(bits)


@dataclasses.dataclass
class PlanDiff:
    """The migrations turning one DeploymentPlan into another.

    Replicas are matched by device-set identity (`ReplicaSpec.key`):
    `removed` replicas exist only in the old plan (their in-flight slots
    must evacuate or migrate), `added` only in the new one, and `changed`
    pairs share devices but differ in layout or any serving dimension
    (role flips re-wire the dispatcher; the executor moves decoding slots
    off replicas that lose decode capability)."""

    removed: List[ReplicaSpec] = dataclasses.field(default_factory=list)
    added: List[ReplicaSpec] = dataclasses.field(default_factory=list)
    changed: List[Tuple[ReplicaSpec, ReplicaSpec]] = \
        dataclasses.field(default_factory=list)      # (old, new) pairs
    dims: FrozenSet[str] = frozenset()               # target plan's dims

    @property
    def is_empty(self) -> bool:
        return not (self.removed or self.added or self.changed)

    def describe(self) -> str:
        if self.is_empty:
            return "no-op"
        bits = []
        if self.removed:
            bits.append("-[" + "; ".join(r.describe()
                                         for r in self.removed) + "]")
        if self.added:
            bits.append("+[" + "; ".join(r.describe()
                                         for r in self.added) + "]")
        for old, new in self.changed:
            bits.append(f"{old.describe()} -> {new.describe()}")
        return ", ".join(bits)


@dataclasses.dataclass
class DeploymentPlan:
    """Per-replica ReplicaSpecs plus the set of searched dimensions.

    This replaces SearchResult's parallel-list fields (roles / spec_ks /
    kv_dtypes / host_blocks): every per-replica decision lives on the
    replica it belongs to, and `dims` records which dimensions the search
    actually ran — the legacy list properties return None for a dimension
    that was never searched, exactly like the old fields did."""

    replicas: List[ReplicaSpec]
    dims: FrozenSet[str] = frozenset()

    @classmethod
    def from_search(cls, assignment: Assignment, *,
                    roles: Optional[Sequence[str]] = None,
                    spec_ks: Optional[Sequence[int]] = None,
                    kv_dtypes: Optional[Sequence[Optional[str]]] = None,
                    host_blocks: Optional[Sequence[int]] = None
                    ) -> "DeploymentPlan":
        """Zip the legacy parallel lists into per-replica specs. A None
        list means that dimension was not searched (dims omits it)."""
        n = assignment.num_replicas
        for name, lst in (("roles", roles), ("spec_ks", spec_ks),
                          ("kv_dtypes", kv_dtypes),
                          ("host_blocks", host_blocks)):
            assert lst is None or len(lst) == n, (name, lst, n)
        reps = [ReplicaSpec(
            pipeline=p,
            role=roles[i] if roles is not None else "both",
            spec_k=int(spec_ks[i]) if spec_ks is not None else 0,
            kv_dtype=kv_dtypes[i] if kv_dtypes is not None else None,
            host_blocks=int(host_blocks[i]) if host_blocks is not None
            else 0)
            for i, p in enumerate(assignment.pipelines)]
        dims = frozenset(d for d, lst in (("roles", roles),
                                          ("spec", spec_ks),
                                          ("kv_dtype", kv_dtypes),
                                          ("host_tier", host_blocks))
                         if lst is not None)
        return cls(replicas=reps, dims=dims)

    # ---- views -----------------------------------------------------------
    @property
    def assignment(self) -> Assignment:
        return Assignment([r.pipeline for r in self.replicas])

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def roles(self) -> Optional[List[str]]:
        return [r.role for r in self.replicas] if "roles" in self.dims \
            else None

    @property
    def spec_ks(self) -> Optional[List[int]]:
        return [r.spec_k for r in self.replicas] if "spec" in self.dims \
            else None

    @property
    def kv_dtypes(self) -> Optional[List[Optional[str]]]:
        return [r.kv_dtype for r in self.replicas] \
            if "kv_dtype" in self.dims else None

    @property
    def host_blocks(self) -> Optional[List[int]]:
        return [r.host_blocks for r in self.replicas] \
            if "host_tier" in self.dims else None

    def validate(self, total_layers: int) -> None:
        self.assignment.validate(total_layers)

    def describe(self) -> str:
        return "; ".join(r.describe() for r in self.replicas)

    # ---- diff / apply ----------------------------------------------------
    def canonical(self) -> "DeploymentPlan":
        """Replicas in a device-order-independent canonical order, so two
        plans built through different routes compare equal."""
        return DeploymentPlan(
            replicas=sorted(self.replicas, key=lambda r: sorted(r.key)),
            dims=self.dims)

    def diff(self, new: "DeploymentPlan") -> PlanDiff:
        """Migrations turning `self` into `new`, keyed by device set."""
        mine = {r.key: r for r in self.replicas}
        theirs = {r.key: r for r in new.replicas}
        assert len(mine) == len(self.replicas), "duplicate device sets"
        assert len(theirs) == len(new.replicas), "duplicate device sets"
        removed = [mine[k] for k in mine if k not in theirs]
        added = [theirs[k] for k in theirs if k not in mine]
        changed = [(mine[k], theirs[k]) for k in mine
                   if k in theirs and mine[k] != theirs[k]]
        return PlanDiff(removed=removed, added=added, changed=changed,
                        dims=new.dims)

    def apply(self, diff: PlanDiff) -> "DeploymentPlan":
        """Apply a diff; `a.apply(a.diff(b)).canonical() == b.canonical()`
        round-trips by construction (the property test's contract)."""
        gone = {r.key for r in diff.removed}
        swap = {old.key: new for old, new in diff.changed}
        reps = [swap.get(r.key, r) for r in self.replicas
                if r.key not in gone]
        reps.extend(diff.added)
        return DeploymentPlan(replicas=reps, dims=diff.dims).canonical()
