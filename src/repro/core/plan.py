"""Assignment dataclasses: the output of the scheduler.

An Assignment maps the device pool onto independent inference pipelines
(model replicas); each pipeline is a list of stages; each stage owns a
disjoint GPU set (its tensor-parallel group) and a contiguous span of layers.
This mirrors the paper's sigma: D -> {(d_ij, l_ij)}.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence


@dataclasses.dataclass
class StagePlan:
    device_ids: List[int]          # the TP group (>=1 devices, same machine/type)
    num_layers: int                # l_ij

    @property
    def tp_degree(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass
class PipelinePlan:
    stages: List[StagePlan]
    cost: float = float("inf")     # end-to-end latency estimate (Eq. 2)
    bottleneck: float = 0.0        # max per-stage time (pipelining throughput)

    @property
    def device_ids(self) -> List[int]:
        return [d for s in self.stages for d in s.device_ids]

    @property
    def layer_split(self) -> List[int]:
        return [s.num_layers for s in self.stages]

    def describe(self) -> str:
        return "[" + ",".join(str(s.tp_degree) for s in self.stages) + "]" \
            + " layers=" + str(self.layer_split)


@dataclasses.dataclass
class Assignment:
    pipelines: List[PipelinePlan]

    def validate(self, total_layers: int) -> None:
        seen = set()
        for p in self.pipelines:
            assert sum(s.num_layers for s in p.stages) == total_layers, \
                (p.layer_split, total_layers)
            for d in p.device_ids:
                assert d not in seen, f"device {d} assigned twice"
                seen.add(d)

    @property
    def num_replicas(self) -> int:
        return len(self.pipelines)

    def describe(self) -> str:
        return "; ".join(p.describe() for p in self.pipelines)
