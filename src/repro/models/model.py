"""Model assembly: init / train-forward / prefill / decode for every family.

Layer stacks are organized into *periods*: the layer pattern of a hybrid
model (e.g. Jamba's mamba x7 + attn, MoE every 2) repeats with period
``period_len(cfg)``; parameters for each period position are stacked over a
leading ``n_periods`` axis and the stack is applied with ``jax.lax.scan`` so
the lowered HLO contains one period body regardless of depth — this keeps
the 512-device dry-run compiles tractable.

Caches are pytrees with the same period stacking and are carried through the
scan as (xs -> ys).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models import layers, mamba, moe, quant, xlstm
from repro.models.quant import mm


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------

def period_len(cfg: ModelConfig) -> int:
    p = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    m = cfg.moe_every if cfg.num_experts else 1
    return math.lcm(p, m)


def n_periods(cfg: ModelConfig) -> int:
    pl = period_len(cfg)
    assert cfg.num_layers % pl == 0, (cfg.name, cfg.num_layers, pl)
    return cfg.num_layers // pl


def sub_kinds(cfg: ModelConfig):
    """Kind + moe flag for each position within one period."""
    return [(cfg.layer_kind(j), cfg.is_moe_layer(j))
            for j in range(period_len(cfg))]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _norm_params(cfg, P, d=None):
    d = d or cfg.d_model
    w = jnp.ones((P, d), _pdt(cfg))
    if cfg.is_encoder_decoder:                      # LayerNorm with bias
        return {"w": w, "b": jnp.zeros((P, d), _pdt(cfg))}
    return {"w": w}


def _pdt(cfg):
    return jnp.dtype(cfg.dtype)


def _rand(key, name, shape, cfg, scale=0.02):
    k = jax.random.fold_in(key, hash(name) % (2 ** 31))
    return (jax.random.normal(k, shape, jnp.float32) * scale).astype(_pdt(cfg))


def _init_attn(key, cfg, P, cross=False):
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "wq": _rand(key, "wq", (P, d, hq * hd), cfg),
        "wk": _rand(key, "wk", (P, d, hkv * hd), cfg),
        "wv": _rand(key, "wv", (P, d, hkv * hd), cfg),
        "wo": _rand(key, "wo", (P, hq * hd, d), cfg, out_scale),
    }
    if cfg.attn_bias and not cross:
        p["bq"] = jnp.zeros((P, hq * hd), _pdt(cfg))
        p["bk"] = jnp.zeros((P, hkv * hd), _pdt(cfg))
        p["bv"] = jnp.zeros((P, hkv * hd), _pdt(cfg))
    return p


def _init_mlp(key, cfg, P):
    d, f = cfg.d_model, cfg.d_ff
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    if cfg.activation == "silu":
        return {"w_gate": _rand(key, "w_gate", (P, d, f), cfg),
                "w_up": _rand(key, "w_up", (P, d, f), cfg),
                "w_down": _rand(key, "w_down", (P, f, d), cfg, out_scale)}
    return {"w_up": _rand(key, "w_up", (P, d, f), cfg),
            "w_down": _rand(key, "w_down", (P, f, d), cfg, out_scale)}


def _init_moe(key, cfg, P):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {"router": _rand(key, "router", (P, d, E), cfg)}
    if cfg.activation == "silu":
        p["w_gate"] = _rand(key, "moe_gate", (P, E, d, f), cfg)
        p["w_up"] = _rand(key, "moe_up", (P, E, d, f), cfg)
    else:
        p["w_up"] = _rand(key, "moe_up", (P, E, d, f), cfg)
    p["w_down"] = _rand(key, "moe_down", (P, E, f, d), cfg, out_scale)
    return p


def _init_mamba(key, cfg, P):
    d = cfg.d_model
    din = mamba.d_inner(cfg)
    dtr = mamba._dt_rank(cfg)
    ds = cfg.ssm_d_state
    w = cfg.ssm_d_conv
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, None],
                 (P, din, 1))
    dt_init = jnp.exp(jax.random.uniform(
        jax.random.fold_in(key, 7), (P, din)) *
        (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))   # inv softplus
    return {
        "in_proj": _rand(key, "in_proj", (P, d, 2 * din), cfg),
        "conv_w": _rand(key, "conv_w", (P, din, w), cfg, 0.1),
        "conv_b": jnp.zeros((P, din), _pdt(cfg)),
        "x_proj": _rand(key, "x_proj", (P, din, dtr + 2 * ds), cfg),
        "dt_proj": _rand(key, "dt_proj", (P, dtr, din), cfg, 0.1),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((P, din), jnp.float32),
        "out_proj": _rand(key, "mam_out", (P, din, d), cfg,
                          0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _init_mlstm(key, cfg, P):
    d = cfg.d_model
    din = xlstm.m_d_inner(cfg)
    qk = xlstm.m_qk_dim(cfg)
    h = cfg.num_heads
    return {
        "w_up": _rand(key, "w_up", (P, d, 2 * din), cfg),
        "wq": _rand(key, "m_wq", (P, din, qk), cfg),
        "wk": _rand(key, "m_wk", (P, din, qk), cfg),
        "wv": _rand(key, "m_wv", (P, din, din), cfg),
        "w_i": _rand(key, "m_wi", (P, din, h), cfg),
        "w_f": _rand(key, "m_wf", (P, din, h), cfg),
        "out_proj": _rand(key, "m_out", (P, din, d), cfg,
                          0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _init_slstm(key, cfg, P):
    d = cfg.d_model
    heads = cfg.num_heads
    dh = d // heads
    p = {"out_proj": _rand(key, "s_out", (P, d, d), cfg,
                           0.02 / math.sqrt(2 * cfg.num_layers))}
    for g in ("z", "i", "f", "o"):
        p[f"w_{g}"] = _rand(key, f"s_w{g}", (P, d, d), cfg)
        p[f"r_{g}"] = _rand(key, f"s_r{g}", (P, heads, dh, dh), cfg)
        b = jnp.zeros((P, d), _pdt(cfg))
        if g == "f":
            b = b + 1.0  # forget-gate bias toward remembering
        p[f"b_{g}"] = b
    return p


def _init_sub(key, cfg, j, kind, is_moe, P):
    key = jax.random.fold_in(key, j)
    sub = {"ln1": _norm_params(cfg, P)}
    if kind == ATTN:
        sub["mixer"] = _init_attn(key, cfg, P)
    elif kind == MAMBA:
        sub["mixer"] = _init_mamba(key, cfg, P)
    elif kind == MLSTM:
        sub["mixer"] = _init_mlstm(key, cfg, P)
    elif kind == SLSTM:
        sub["mixer"] = _init_slstm(key, cfg, P)
    if cfg.is_encoder_decoder:
        sub["lnx"] = _norm_params(cfg, P)
        sub["xattn"] = _init_attn(jax.random.fold_in(key, 91), cfg, P,
                                  cross=True)
    has_mlp = cfg.d_ff > 0 and kind in (ATTN, MAMBA)
    if has_mlp:
        sub["ln2"] = _norm_params(cfg, P)
        if is_moe:
            sub["moe"] = _init_moe(jax.random.fold_in(key, 17), cfg, P)
        else:
            sub["mlp"] = _init_mlp(jax.random.fold_in(key, 19), cfg, P)
    return sub


def init_params(cfg: ModelConfig, key):
    P = n_periods(cfg)
    params = {
        "embed": _rand(key, "embed", (cfg.vocab_size, cfg.d_model), cfg),
        "final_norm": {"w": jnp.ones((cfg.d_model,), _pdt(cfg)),
                       **({"b": jnp.zeros((cfg.d_model,), _pdt(cfg))}
                          if cfg.is_encoder_decoder else {})},
        "blocks": {
            f"sub{j}": _init_sub(key, cfg, j, kind, is_moe, P)
            for j, (kind, is_moe) in enumerate(sub_kinds(cfg))
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _rand(key, "lm_head",
                                  (cfg.d_model, cfg.vocab_size), cfg)
    if cfg.is_encoder_decoder:
        Pe = cfg.num_encoder_layers
        ekey = jax.random.fold_in(key, 1234)
        params["encoder"] = {
            "blocks": {"sub0": {
                "ln1": _norm_params(cfg, Pe),
                "mixer": _init_attn(ekey, cfg, Pe),
                "ln2": _norm_params(cfg, Pe),
                "mlp": _init_mlp(jax.random.fold_in(ekey, 3), cfg, Pe),
            }},
            "final_norm": {"w": jnp.ones((cfg.d_model,), _pdt(cfg)),
                           "b": jnp.zeros((cfg.d_model,), _pdt(cfg))},
        }
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Stacked (n_periods, ...) cache pytree. max_len = prompt + new tokens."""
    P = n_periods(cfg)
    dt = dtype or _pdt(cfg)
    hd = cfg.head_dim_
    cache = {}
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        c = {}
        if kind == ATTN:
            S = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
            c["k"] = jnp.zeros((P, batch, S, cfg.num_kv_heads, hd), dt)
            c["v"] = jnp.zeros((P, batch, S, cfg.num_kv_heads, hd), dt)
            if cfg.is_encoder_decoder:
                c["cross_k"] = jnp.zeros(
                    (P, batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dt)
                c["cross_v"] = jnp.zeros(
                    (P, batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dt)
        elif kind == MAMBA:
            din = mamba.d_inner(cfg)
            c["conv"] = jnp.zeros((P, batch, cfg.ssm_d_conv - 1, din), dt)
            c["h"] = jnp.zeros((P, batch, din, cfg.ssm_d_state), jnp.float32)
        elif kind == MLSTM:
            h = cfg.num_heads
            qk_h = xlstm.m_qk_dim(cfg) // h
            v_h = xlstm.m_d_inner(cfg) // h
            c["C"] = jnp.zeros((P, batch, h, qk_h, v_h), jnp.float32)
            c["n"] = jnp.zeros((P, batch, h, qk_h), jnp.float32)
        elif kind == SLSTM:
            for nm in ("c", "n", "m", "h"):
                c[nm] = jnp.zeros((P, batch, cfg.d_model), jnp.float32)
        cache[f"sub{j}"] = c
    return cache


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     n_slots: int, dtype=None, kv_dtype=None):
    """Stacked (n_periods, ...) PAGED cache pytree.

    Attention sublayers get page pools ``(P, n_blocks, block_size, hkv, hd)``
    shared by every in-flight sequence and addressed through per-request
    block tables (serving.block_manager); recurrent-state sublayers
    (Mamba/xLSTM) keep their O(1) per-slot states exactly as in the
    contiguous layout — there is nothing to page. Block 0 of each pool is
    the reserved null/trash page.

    kv_dtype (models/quant.KV_DTYPES) selects the pool precision: None
    keeps the legacy behavior (``dtype`` or the model dtype), "fp32"/"bf16"
    force an unquantized pool at that width, and "int8"/"fp8" store scaled
    payloads with float32 per-token-per-head scale pools ``k_scale`` /
    ``v_scale`` of shape (P, n_blocks, block_size, hkv) alongside the
    payload — addressed by the same block ids, so COW / truncate /
    migration treat them as just another pool leaf.

    SWA ring caches and encoder-decoder cross-KV stay on the contiguous
    path (slot mode already excludes them — serving.pipeline.
    slot_mode_supported).
    """
    assert not (cfg.swa_window or cfg.is_encoder_decoder), \
        "paged layout covers full-KV text decoders"
    P = n_periods(cfg)
    quantized = kv_dtype is not None and quant.kv_is_quantized(kv_dtype)
    if kv_dtype is None:
        dt = dtype or _pdt(cfg)
    else:
        dt = quant.kv_storage_dtype(kv_dtype)
    hd = cfg.head_dim_
    kinds = sub_kinds(cfg)
    slot_states = None
    if any(kind != ATTN for kind, _ in kinds):
        slot_states = init_cache(cfg, n_slots, 1, dtype)
    cache = {}
    for j, (kind, _) in enumerate(kinds):
        if kind == ATTN:
            c = {"k": jnp.zeros((P, n_blocks, block_size, cfg.num_kv_heads,
                                 hd), dt),
                 "v": jnp.zeros((P, n_blocks, block_size, cfg.num_kv_heads,
                                 hd), dt)}
            if quantized:
                shape = (P, n_blocks, block_size, cfg.num_kv_heads)
                c["k_scale"] = jnp.zeros(shape, jnp.float32)
                c["v_scale"] = jnp.zeros(shape, jnp.float32)
        else:
            c = slot_states[f"sub{j}"]
        cache[f"sub{j}"] = c
    return cache


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _norm(cfg, p, x):
    if cfg.is_encoder_decoder:
        return layers.layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return layers.rms_norm(x, p["w"], cfg.norm_eps)


def apply_sublayer_seq(cfg, kind, sp, x, sc, *, positions, kv_start, valid,
                       enc_out, mode, lens=None):
    """One block (mixer [+ cross-attn] [+ MLP/MoE]) over a full sequence.
    mode: 'train' (no cache) | 'prefill' (write cache).
    lens (b,) marks RIGHT-padded rows (slot insertion); kv_start marks
    LEFT-padded rows (static batching). Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, sp["ln1"], x)
    if kind == ATTN:
        mixer_cache = None
        if mode == "prefill" and sc is not None:
            mixer_cache = {"k": sc["k"], "v": sc["v"]}
        o, mc = layers.attn_prefill(sp["mixer"], h, cfg, positions=positions,
                                    kv_start=kv_start, cache=mixer_cache)
        nc = dict(mc) if mc is not None else {}
    elif kind == MAMBA:
        o, mc = mamba.mamba_prefill(sp["mixer"], h, cfg, valid=valid,
                                    lens=lens,
                                    cache=sc if mode == "prefill" else None)
        nc = mc or {}
    elif kind == MLSTM:
        o, mc = xlstm.mlstm_prefill(sp["mixer"], h, cfg, valid=valid,
                                    cache=sc if mode == "prefill" else None)
        nc = mc or {}
    elif kind == SLSTM:
        o, mc = xlstm.slstm_prefill(sp["mixer"], h, cfg, valid=valid,
                                    cache=sc if mode == "prefill" else None)
        nc = mc or {}
    x = x + o
    if cfg.is_encoder_decoder:
        hx = _norm(cfg, sp["lnx"], x)
        if mode == "prefill" and sc is not None:
            o, ekv = layers.cross_attn(sp["xattn"], hx, cfg, enc_out=enc_out)
            nc["cross_k"] = ekv["k"].astype(sc["cross_k"].dtype)
            nc["cross_v"] = ekv["v"].astype(sc["cross_v"].dtype)
        else:
            o, _ = layers.cross_attn(sp["xattn"], hx, cfg, enc_out=enc_out)
        x = x + o
    if "mlp" in sp:
        x = x + layers.mlp(sp["mlp"], _norm(cfg, sp["ln2"], x), cfg)
    elif "moe" in sp:
        o, a = moe.moe_mlp(sp["moe"], _norm(cfg, sp["ln2"], x), cfg,
                           return_aux=True)
        x = x + o
        aux = aux + a
    return x, nc, aux


def apply_sublayer_decode(cfg, kind, sp, x, sc, *, pos, kv_start):
    """One block for a single decode token. Returns (x, new_cache)."""
    h = _norm(cfg, sp["ln1"], x)
    if kind == ATTN:
        o, mc = layers.attn_decode(sp["mixer"], h, cfg, pos=pos,
                                   kv_start=kv_start,
                                   cache={"k": sc["k"], "v": sc["v"]})
        nc = dict(mc)
        if cfg.is_encoder_decoder:
            nc["cross_k"], nc["cross_v"] = sc["cross_k"], sc["cross_v"]
    elif kind == MAMBA:
        o, nc = mamba.mamba_decode(sp["mixer"], h, cfg, cache=sc)
    elif kind == MLSTM:
        o, nc = xlstm.mlstm_decode(sp["mixer"], h, cfg, cache=sc)
    elif kind == SLSTM:
        o, nc = xlstm.slstm_decode(sp["mixer"], h, cfg, cache=sc)
    x = x + o
    if cfg.is_encoder_decoder:
        hx = _norm(cfg, sp["lnx"], x)
        o, _ = layers.cross_attn(
            sp["xattn"], hx, cfg,
            enc_kv={"k": sc["cross_k"], "v": sc["cross_v"]})
        x = x + o
    if "mlp" in sp:
        x = x + layers.mlp(sp["mlp"], _norm(cfg, sp["ln2"], x), cfg)
    elif "moe" in sp:
        x = x + moe.moe_mlp(sp["moe"], _norm(cfg, sp["ln2"], x), cfg)
    return x, nc


def _paged_attn_cache(sc):
    """The attention leaves of one sublayer's paged cache — payload pools
    plus, for quantized pools, their scale companions."""
    return {n: sc[n] for n in ("k", "v", "k_scale", "v_scale") if n in sc}


def apply_sublayer_decode_paged(cfg, kind, sp, x, sc, *, pos,
                                block_tables):
    """One block for a single decode token against a PAGED cache.
    Attention sublayers address page pools through `block_tables`;
    recurrent-state sublayers are identical to the contiguous path (their
    cache rows ARE the slots). Returns (x, new_cache)."""
    h = _norm(cfg, sp["ln1"], x)
    if kind == ATTN:
        o, nc = layers.attn_decode_paged(sp["mixer"], h, cfg, pos=pos,
                                         block_tables=block_tables,
                                         cache=_paged_attn_cache(sc))
    elif kind == MAMBA:
        o, nc = mamba.mamba_decode(sp["mixer"], h, cfg, cache=sc)
    elif kind == MLSTM:
        o, nc = xlstm.mlstm_decode(sp["mixer"], h, cfg, cache=sc)
    elif kind == SLSTM:
        o, nc = xlstm.slstm_decode(sp["mixer"], h, cfg, cache=sc)
    x = x + o
    if "mlp" in sp:
        x = x + layers.mlp(sp["mlp"], _norm(cfg, sp["ln2"], x), cfg)
    elif "moe" in sp:
        x = x + moe.moe_mlp(sp["moe"], _norm(cfg, sp["ln2"], x), cfg)
    return x, nc


def apply_sublayer_context_paged(cfg, kind, sp, x, sc, *, positions, q_len,
                                 block_tables):
    """One block over a CHUNK of new tokens against a PAGED cache: the
    chunk's K/V scatter into pages and attention reads the prior context
    back through `block_tables` (layers.attn_context_paged) — the
    warm-prefix / chunked-prefill path. Attention-only by construction:
    a recurrent sublayer's state is a running summary with no per-block
    identity to share or resume, so hybrid stacks keep the one-shot
    prefill (serving.pipeline.context_mode_supported gates this).
    Returns (x, new_cache)."""
    assert kind == ATTN, \
        "paged context prefill covers attention-only stacks " \
        "(recurrent state cannot be resumed per block)"
    h = _norm(cfg, sp["ln1"], x)
    o, nc = layers.attn_context_paged(sp["mixer"], h, cfg,
                                      positions=positions, q_len=q_len,
                                      block_tables=block_tables,
                                      cache=_paged_attn_cache(sc))
    x = x + o
    if "mlp" in sp:
        x = x + layers.mlp(sp["mlp"], _norm(cfg, sp["ln2"], x), cfg)
    elif "moe" in sp:
        x = x + moe.moe_mlp(sp["moe"], _norm(cfg, sp["ln2"], x), cfg)
    return x, nc


def apply_sublayer_verify_paged(cfg, kind, sp, x, sc, *, positions, q_len,
                                block_tables):
    """One block over a slot's CANDIDATE CHUNK (bonus token + draft
    proposals) against a PAGED cache — the speculative-decoding
    verification step. The chunk's K/V scatter into pages at the slot's
    committed offset and every candidate attends to the committed context
    plus the candidate prefix (layers.attn_verify_paged); the caller reads
    the head at EVERY chunk position to run acceptance. Attention-only by
    construction, like the context path: a recurrent sublayer's state
    cannot be rolled back when candidates are rejected.
    Returns (x, new_cache)."""
    assert kind == ATTN, \
        "paged verification covers attention-only stacks " \
        "(recurrent state cannot be rolled back on rejection)"
    h = _norm(cfg, sp["ln1"], x)
    o, nc = layers.attn_verify_paged(sp["mixer"], h, cfg,
                                     positions=positions, q_len=q_len,
                                     block_tables=block_tables,
                                     cache=_paged_attn_cache(sc))
    x = x + o
    if "mlp" in sp:
        x = x + layers.mlp(sp["mlp"], _norm(cfg, sp["ln2"], x), cfg)
    elif "moe" in sp:
        x = x + moe.moe_mlp(sp["moe"], _norm(cfg, sp["ln2"], x), cfg)
    return x, nc


def _apply_period_verify_paged(cfg, pp, x, cache_p, *, positions, q_len,
                               block_tables):
    new_cache = {}
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        x, nc = apply_sublayer_verify_paged(
            cfg, kind, pp[f"sub{j}"], x, cache_p[f"sub{j}"],
            positions=positions, q_len=q_len, block_tables=block_tables)
        new_cache[f"sub{j}"] = nc
    return x, new_cache


def _apply_period_context_paged(cfg, pp, x, cache_p, *, positions, q_len,
                                block_tables):
    new_cache = {}
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        x, nc = apply_sublayer_context_paged(
            cfg, kind, pp[f"sub{j}"], x, cache_p[f"sub{j}"],
            positions=positions, q_len=q_len, block_tables=block_tables)
        new_cache[f"sub{j}"] = nc
    return x, new_cache


def _apply_period_seq(cfg, pp, x, cache_p, *, positions, kv_start, valid,
                      enc_out, mode, lens=None):
    new_cache = {}
    aux = jnp.zeros((), jnp.float32)
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        sc = cache_p.get(f"sub{j}") if cache_p is not None else None
        x, nc, a = apply_sublayer_seq(cfg, kind, pp[f"sub{j}"], x, sc,
                                      positions=positions, kv_start=kv_start,
                                      valid=valid, enc_out=enc_out, mode=mode,
                                      lens=lens)
        aux = aux + a
        new_cache[f"sub{j}"] = nc
    return x, new_cache, aux


def _apply_period_decode(cfg, pp, x, cache_p, *, pos, kv_start):
    new_cache = {}
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        x, nc = apply_sublayer_decode(cfg, kind, pp[f"sub{j}"], x,
                                      cache_p[f"sub{j}"], pos=pos,
                                      kv_start=kv_start)
        new_cache[f"sub{j}"] = nc
    return x, new_cache


def _apply_period_decode_paged(cfg, pp, x, cache_p, *, pos, block_tables):
    new_cache = {}
    for j, (kind, _) in enumerate(sub_kinds(cfg)):
        x, nc = apply_sublayer_decode_paged(cfg, kind, pp[f"sub{j}"], x,
                                            cache_p[f"sub{j}"], pos=pos,
                                            block_tables=block_tables)
        new_cache[f"sub{j}"] = nc
    return x, new_cache


# Activation checkpointing for training: recompute each period in the
# backward pass instead of saving its internals (the flash-attention chunk
# stats would otherwise grow O(s^2)). Policy is swappable for perf studies.
REMAT_TRAIN = True
REMAT_POLICY = None            # e.g. jax.checkpoint_policies.dots_saveable


def _scan_stack(cfg, blocks, x, cache, body):
    """scan over the period axis. cache may be None (train mode)."""
    if cache is None:
        def f(x, pp):
            x, _, aux = body(x, pp, None)
            return x, aux
        if REMAT_TRAIN:
            f = jax.checkpoint(f, policy=REMAT_POLICY)
        x, auxs = jax.lax.scan(f, x, blocks)
        return x, None, auxs.sum()

    def f(x, per):
        pp, cp = per
        x, nc, aux = body(x, pp, cp)
        return x, (nc, aux)

    x, (new_cache, auxs) = jax.lax.scan(f, x, (blocks, cache))
    return x, new_cache, auxs.sum()


# ---------------------------------------------------------------------------
# Per-layer access (asymmetric pipeline executor: stages hold arbitrary
# contiguous layer ranges, so they index into the period-stacked params)
# ---------------------------------------------------------------------------

def layer_sub_index(cfg: ModelConfig, i: int):
    """Global layer i -> (period index, sub index within period)."""
    pl = period_len(cfg)
    return i // pl, i % pl


def slice_layer_params(cfg: ModelConfig, params, i: int):
    """Un-stacked params of global layer i (leading period dim removed)."""
    p, j = layer_sub_index(cfg, i)
    return jax.tree.map(lambda l: l[p], params["blocks"][f"sub{j}"])


def init_layer_cache(cfg: ModelConfig, i: int, batch: int, max_len: int,
                     dtype=None):
    """Single-layer cache (no period axis)."""
    p, j = layer_sub_index(cfg, i)
    full = init_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(lambda l: l[0], full[f"sub{j}"])


def init_layer_paged_cache(cfg: ModelConfig, i: int, n_blocks: int,
                           block_size: int, n_slots: int, dtype=None,
                           kv_dtype=None, kv_guard_layers=()):
    """Single-layer PAGED cache (no period axis): attention layers get a
    page pool, recurrent layers their per-slot states.

    kv_guard_layers is the quality guard: global layer indices in it keep
    the model-default (unquantized) pool precision whatever ``kv_dtype``
    says — attention sinks concentrate in the first/last layers, so
    pinning those limits the quantization error where it compounds."""
    if i in kv_guard_layers:
        kv_dtype = None
    p, j = layer_sub_index(cfg, i)
    full = init_paged_cache(cfg, n_blocks, block_size, n_slots, dtype,
                            kv_dtype=kv_dtype)
    return jax.tree.map(lambda l: l[0], full[f"sub{j}"])


# ---------------------------------------------------------------------------
# Slot cache pools (continuous batching): a replica owns one pre-allocated
# cache whose batch rows are SLOTS; inserting a request scatters its freshly
# prefilled cache rows over the free slots, fully replacing whatever a
# previous occupant left there. batch_axis=0 covers the per-layer caches of
# the asymmetric pipeline; batch_axis=1 the period-stacked monolithic cache.
# ---------------------------------------------------------------------------

def scatter_cache_rows(pool, rows, slot_ids, *, batch_axis=0):
    """Write `rows` (cache pytree, batch = len(slot_ids)) into `pool` at the
    given slot indices. Row seq lengths must match the pool's."""
    idx = jnp.asarray(slot_ids, jnp.int32)

    def put(big, small):
        if batch_axis == 0:
            return big.at[idx].set(small.astype(big.dtype))
        return big.at[:, idx].set(small.astype(big.dtype))

    return jax.tree.map(put, pool, rows)


def scatter_rows_to_pages(pages, rows, dest_blocks, *, batch_axis=0):
    """Write freshly prefilled contiguous cache rows into a PAGED pool.

    pages: {"k","v"} page pools (n_blocks, bs, h, d), or period-stacked
        (P, n_blocks, bs, h, d) with batch_axis=1.
    rows:  {"k","v"} contiguous rows (m, S, h, d) (resp. (P, m, S, h, d))
        with S a multiple of the block size.
    dest_blocks: (m * S // bs,) int32 physical page of each (row, logical
        block) pair, row-major; unallocated tail entries point at the null
        page and their (garbage, past-lens) contents are never unmasked.

    A QUANTIZED pool (``"k_scale"`` present) quantizes on write: each K/V
    row is split into an int8/fp8 payload plus per-token-per-head scales
    (models/quant.quantize_kv_rows, scheme inferred from the payload
    dtype), and both scatter through the same dest_blocks.
    """
    dest = jnp.asarray(dest_blocks, jnp.int32)

    def put(pool, row):
        if batch_axis == 0:
            m, S, h, d = row.shape
            bs = pool.shape[1]
            blocks = row.reshape(m * (S // bs), bs, h, d)
            return pool.at[dest].set(blocks.astype(pool.dtype))
        P, m, S, h, d = row.shape
        bs = pool.shape[2]
        blocks = row.reshape(P, m * (S // bs), bs, h, d)
        return pool.at[:, dest].set(blocks.astype(pool.dtype))

    def put_scale(pool, row):
        if batch_axis == 0:
            m, S, h = row.shape
            bs = pool.shape[1]
            blocks = row.reshape(m * (S // bs), bs, h)
            return pool.at[dest].set(blocks)
        P, m, S, h = row.shape
        bs = pool.shape[2]
        blocks = row.reshape(P, m * (S // bs), bs, h)
        return pool.at[:, dest].set(blocks)

    if isinstance(pages, dict) and "k_scale" in pages:
        kvd = quant.kv_dtype_name(pages["k"].dtype)
        out = {}
        for n in ("k", "v"):
            payload, sc = quant.quantize_kv_rows(rows[n], kvd)
            out[n] = put(pages[n], payload)
            out[n + "_scale"] = put_scale(pages[n + "_scale"], sc)
        return out
    return jax.tree.map(put, pages, rows)


def copy_cache_pages(cache, src_blocks, dst_blocks, *, stacked=True):
    """Copy-on-write support: duplicate page contents src -> dst in every
    attention K/V pool of a paged cache pytree (init_paged_cache layout
    when stacked=True, init_layer_paged_cache when False). Recurrent-state
    leaves are untouched — they are per-slot, never shared."""
    src = jnp.asarray(src_blocks, jnp.int32)
    dst = jnp.asarray(dst_blocks, jnp.int32)

    def one(c):
        if not (isinstance(c, dict) and "k" in c and "v" in c):
            return c
        out = dict(c)
        for n in ("k", "v", "k_scale", "v_scale"):
            if n not in c:
                continue
            if stacked:
                out[n] = c[n].at[:, dst].set(c[n][:, src])
            else:
                out[n] = c[n].at[dst].set(c[n][src])
        return out

    return {name: one(c) for name, c in cache.items()} \
        if isinstance(cache, dict) and all(
            isinstance(v, dict) for v in cache.values()) else one(cache)


def scatter_cache_rows_paged(pool, rows, slot_ids, dest_blocks, *,
                             batch_axis=0):
    """Paged counterpart of ``scatter_cache_rows`` for one sublayer's cache:
    attention K/V leaves scatter into pages via `dest_blocks`; every other
    leaf (recurrent states) scatters by slot id exactly as the contiguous
    path does."""
    if "k" in pool and "v" in pool:
        kv_names = ("k", "v", "k_scale", "v_scale")
        paged_part = scatter_rows_to_pages(
            {n: pool[n] for n in kv_names if n in pool},
            {"k": rows["k"], "v": rows["v"]},
            dest_blocks, batch_axis=batch_axis)
        rest_pool = {n: l for n, l in pool.items() if n not in kv_names}
        rest_rows = {n: l for n, l in rows.items() if n not in kv_names}
        out = dict(paged_part)
        if rest_pool:
            out.update(scatter_cache_rows(rest_pool, rest_rows, slot_ids,
                                          batch_axis=batch_axis))
        return out
    return scatter_cache_rows(pool, rows, slot_ids, batch_axis=batch_axis)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.family == "vlm":                      # gemma-style scaling
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(cfg, params, x):
    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return mm(x, params["lm_head"])


def _encoder_forward(cfg, params, frames):
    """Whisper encoder over stub frame embeddings (b, se, d)."""
    b, se, d = frames.shape
    pos = jnp.arange(se)[None].repeat(b, 0)
    x = frames + layers.sinusoidal_positions(pos, d).astype(frames.dtype)
    ep = params["encoder"]

    def body(x, pp):
        h = _norm(cfg, pp["ln1"], x)
        x = x + layers.attn_encoder(pp["mixer"], h, cfg)
        x = x + layers.mlp(pp["mlp"], _norm(cfg, pp["ln2"], x), cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, ep["blocks"]["sub0"])
    return layers.layer_norm(x, ep["final_norm"]["w"], ep["final_norm"]["b"],
                             cfg.norm_eps)


def _prep_input_seq(cfg, params, batch):
    """tokens (+ modality stubs) -> (x, positions, extra_prefix_len)."""
    tokens = batch["tokens"]
    b, st = tokens.shape
    x = _embed(cfg, params, tokens)
    prefix = 0
    if cfg.num_image_tokens:
        img = batch["image_embeds"].astype(x.dtype)   # (b, n_img, d)
        x = jnp.concatenate([img, x], axis=1)
        prefix = cfg.num_image_tokens
    s = x.shape[1]
    positions = jnp.arange(s)[None].repeat(b, 0)
    if cfg.is_encoder_decoder and cfg.rope_theta == 0.0:
        x = x + layers.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x, positions, prefix


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def train_forward(cfg: ModelConfig, params, batch):
    """Full-sequence causal logits for training.
    batch: {"tokens": (b,s)} + optional "image_embeds"/"enc_frames".
    Returns (logits (b, s_total, V), aux_loss)."""
    x, positions, _ = _prep_input_seq(cfg, params, batch)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params, batch["enc_frames"])

    def body(x, pp, cp):
        return _apply_period_seq(cfg, pp, x, cp, positions=positions,
                                 kv_start=None, valid=None, enc_out=enc_out,
                                 mode="train")

    x, _, aux = _scan_stack(cfg, params["blocks"], x, None, body)
    return _head(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token cross entropy over the text positions."""
    logits, aux = train_forward(cfg, params, batch)
    tokens = batch["tokens"]
    prefix = cfg.num_image_tokens
    logits = logits[:, prefix:, :]
    pred = logits[:, :-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


def prefill(cfg: ModelConfig, params, batch, cache, *, kv_start=None,
            lens=None):
    """Prompt pass; fills cache; returns (last-position logits (b,V), cache).

    Two padding conventions:
      * kv_start (b,): LEFT-padded rows (static batching) — pads consume the
        leading positions; logits read at the uniform last position.
      * lens (b,): RIGHT-padded rows (continuous-batching slot insertion) —
        row i's prompt occupies [0, lens[i]); trailing pads are masked to
        identity steps and the logits are gathered at each row's own last
        real token. Token positions then match isolated generation exactly,
        so a row's computation is independent of its batch-mates.
    """
    assert kv_start is None or lens is None, "pick one padding convention"
    x, positions, _ = _prep_input_seq(cfg, params, batch)
    b, s = x.shape[:2]
    valid = None
    if kv_start is not None:
        valid = (jnp.arange(s)[None, :] >= kv_start[:, None]).astype(jnp.int32)
    if lens is not None:
        valid = (jnp.arange(s)[None, :] < lens[:, None]).astype(jnp.int32)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params, batch["enc_frames"])

    def body(x, pp, cp):
        return _apply_period_seq(cfg, pp, x, cp, positions=positions,
                                 kv_start=kv_start, valid=valid,
                                 enc_out=enc_out, mode="prefill", lens=lens)

    x, new_cache, _ = _scan_stack(cfg, params["blocks"], x, cache, body)
    if lens is not None:
        x_last = x[jnp.arange(b), lens - 1][:, None]
    else:
        x_last = x[:, -1:, :]
    logits = _head(cfg, params, x_last)[:, 0]
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, tokens, cache, pos, *,
                kv_start=None):
    """One decode step. tokens (b,); pos: scalar absolute position of the
    new token (uniform batch, left-padded prompts) or an int32 (b,) array of
    per-row positions (continuous batching)."""
    x = _embed(cfg, params, tokens[:, None])
    if cfg.is_encoder_decoder and cfg.rope_theta == 0.0:
        b = tokens.shape[0]
        pos_a = jnp.asarray(pos)
        posb = pos_a[:, None] if pos_a.ndim else jnp.full((b, 1), pos_a)
        x = x + layers.sinusoidal_positions(posb, cfg.d_model).astype(x.dtype)

    def f(x, per):
        pp, cp = per
        x, nc = _apply_period_decode(cfg, pp, x, cp, pos=pos,
                                     kv_start=kv_start)
        return x, nc

    x, new_cache = jax.lax.scan(f, x, (params["blocks"], cache))
    logits = _head(cfg, params, x)[:, 0]
    return logits, new_cache


def prefill_paged_context(cfg: ModelConfig, params, tokens, cache, q_start,
                          q_len, block_tables):
    """CONTEXT PREFILL against the PAGED cache: run a chunk of new tokens
    (b, C) whose row-i token j sits at absolute position q_start[i] + j,
    attending to the pages holding [0, q_start) plus itself causally, and
    scatter the chunk's K/V into the pages through `block_tables`
    (b, max_blocks). This is how a warm-prefix request prefills only its
    cold suffix and how a long prompt prefills in fixed-size chunks.
    q_len (b,) real chunk lengths (trailing pads write the null page).
    Returns (last-real-token logits (b, V), cache). Attention-only stacks
    (apply_sublayer_context_paged asserts)."""
    x = _embed(cfg, params, tokens)
    b, C = tokens.shape
    starts = jnp.asarray(q_start, jnp.int32)
    lens = jnp.asarray(q_len, jnp.int32)
    positions = starts[:, None] + jnp.arange(C)[None]
    bt = jnp.asarray(block_tables, jnp.int32)

    def f(x, per):
        pp, cp = per
        x, nc = _apply_period_context_paged(cfg, pp, x, cp,
                                            positions=positions, q_len=lens,
                                            block_tables=bt)
        return x, nc

    x, new_cache = jax.lax.scan(f, x, (params["blocks"], cache))
    x_last = x[jnp.arange(b), lens - 1][:, None]
    logits = _head(cfg, params, x_last)[:, 0]
    return logits, new_cache


def verify_step_paged(cfg: ModelConfig, params, tokens, cache, kv_start,
                      q_len, block_tables):
    """MULTI-TOKEN VERIFICATION against the PAGED cache: run each row's
    candidate chunk `tokens` (b, T) — bonus token + draft proposals, row
    i's candidate j at absolute position kv_start[i] + j — in ONE forward
    pass, scattering the chunk's K/V through `block_tables`
    (b, max_blocks) and returning logits at EVERY chunk position:
    (logits (b, T, V), cache). Greedy acceptance then commits the longest
    candidate prefix matching the argmax chain; rejected candidates' page
    writes sit past the committed length (masked, overwritten next step).
    q_len (b,) real candidate counts (rows with 0 are dead padding).
    Attention-only stacks (apply_sublayer_verify_paged asserts)."""
    x = _embed(cfg, params, tokens)
    b, T = tokens.shape
    starts = jnp.asarray(kv_start, jnp.int32)
    lens = jnp.asarray(q_len, jnp.int32)
    positions = starts[:, None] + jnp.arange(T)[None]
    bt = jnp.asarray(block_tables, jnp.int32)

    def f(x, per):
        pp, cp = per
        x, nc = _apply_period_verify_paged(cfg, pp, x, cp,
                                           positions=positions, q_len=lens,
                                           block_tables=bt)
        return x, nc

    x, new_cache = jax.lax.scan(f, x, (params["blocks"], cache))
    return _head(cfg, params, x), new_cache


def decode_step_paged(cfg: ModelConfig, params, tokens, cache, pos,
                      block_tables):
    """One decode step against the PAGED cache (init_paged_cache layout).
    tokens (b,); pos (b,) per-row absolute positions; block_tables
    (b, max_blocks) int32, shared by every layer (each period's page pools
    are indexed with the same table)."""
    x = _embed(cfg, params, tokens[:, None])
    bt = jnp.asarray(block_tables, jnp.int32)

    def f(x, per):
        pp, cp = per
        x, nc = _apply_period_decode_paged(cfg, pp, x, cp, pos=pos,
                                           block_tables=bt)
        return x, nc

    x, new_cache = jax.lax.scan(f, x, (params["blocks"], cache))
    logits = _head(cfg, params, x)[:, 0]
    return logits, new_cache
