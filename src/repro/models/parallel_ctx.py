"""Ambient parallel context for model code running under the production
mesh. Launchers (dryrun/train/serve) set this; CPU unit tests leave it
unset and models take their local (GSPMD-free) paths.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

MESH = None                         # jax.sharding.Mesh
DATA_AXES: Tuple[str, ...] = ()     # ("data",) or ("pod", "data")
MODEL_AXIS: Optional[str] = None    # "model"


@contextlib.contextmanager
def use_mesh(mesh, data_axes, model_axis):
    global MESH, DATA_AXES, MODEL_AXIS
    prev = (MESH, DATA_AXES, MODEL_AXIS)
    MESH, DATA_AXES, MODEL_AXIS = mesh, tuple(data_axes), model_axis
    try:
        yield
    finally:
        MESH, DATA_AXES, MODEL_AXIS = prev


def active() -> bool:
    return MESH is not None
