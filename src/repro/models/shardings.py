"""Parameter/activation PartitionSpec rules (Megatron-style TP) used by both
the asymmetric pipeline executor (per-stage meshes) and the production-mesh
dry-run.

Column-parallel: wq/wk/wv, w_gate/w_up, mamba in_proj  -> shard output dim
Row-parallel:    wo, w_down, mamba/mlstm out_proj      -> shard input dim
Experts:         (E,d,f) shards E over 'model' when E % tp == 0, else d_ff
Embedding:       vocab-sharded; lm_head vocab-sharded
KV heads:        sharded only when num_kv_heads % tp == 0, else replicated
                 (granite-20b MQA, granite-8b kv=8 on tp=16 -> replicated)
Anything unmatched is replicated. The sublayer kind (attention vs mamba vs
mLSTM vs sLSTM) is recovered from the ``subJ`` path element so shared leaf
names (wq/wk/wv) resolve correctly.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig
from repro.models.model import sub_kinds


def _div(n: int, tp: int) -> bool:
    return tp > 0 and n % tp == 0


def param_specs(cfg: ModelConfig, params, *, model_axis: str = "model",
                tp: int = 1):
    """PartitionSpec pytree matching `params`. Leaves inside params["blocks"]
    (and encoder blocks) carry a leading period axis -> prepend None."""
    m = model_axis if tp > 1 else None
    kinds = sub_kinds(cfg)
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    E, f = cfg.num_experts, cfg.d_ff
    din = cfg.ssm_expand * cfg.d_model
    qk = int(din * cfg.xlstm_qk_dim_factor)
    heads = cfg.num_heads

    def spec(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = names[-1]
        stacked = "blocks" in names
        in_moe = "moe" in names
        in_mixer = "mixer" in names
        kind = ATTN
        for n in names:
            if isinstance(n, str) and n.startswith("sub") and n != "sub":
                if "encoder" not in names:
                    kind = kinds[int(n[3:])][0]

        def wrap(*dims):
            dims = list(dims) + [None] * (leaf.ndim - len(dims)
                                          - (1 if stacked else 0))
            return P(*([None] + dims if stacked else dims))

        if m is None:
            return wrap()
        if name == "embed":
            return P(m if _div(cfg.vocab_size, tp) else None, None)
        if name == "lm_head":
            return P(None, m if _div(cfg.vocab_size, tp) else None)

        if in_mixer and kind in (ATTN,) or name in ("wq", "wk", "wv", "wo",
                                                    "bq", "bk", "bv") \
                and kind == ATTN:
            if name == "wq":
                return wrap(None, m) if _div(hq * hd, tp) else wrap()
            if name == "bq":
                return wrap(m) if _div(hq * hd, tp) else wrap()
            if name in ("wk", "wv"):
                return wrap(None, m) if _div(hkv, tp) else wrap()
            if name in ("bk", "bv"):
                return wrap(m) if _div(hkv, tp) else wrap()
            if name == "wo":
                return wrap(m, None) if _div(hq * hd, tp) else wrap()

        if in_mixer and kind == MAMBA:
            sd = _div(din, tp)
            if name == "in_proj":
                return wrap(None, m) if sd else wrap()
            if name in ("conv_w", "conv_b", "A_log", "D", "dt_bias",
                        "x_proj"):
                return wrap(m) if sd else wrap()
            if name == "dt_proj":
                return wrap(None, m) if sd else wrap()
            if name == "out_proj":
                return wrap(m, None) if sd else wrap()

        if in_mixer and kind == MLSTM:
            sd = _div(din, tp)
            if name == "w_up":
                return wrap(None, m) if sd else wrap()
            if name in ("wq", "wk", "wv", "w_i", "w_f"):
                return wrap(m, None) if sd else wrap()
            if name == "out_proj":
                return wrap()                 # y replicated after psum
            return wrap()

        if in_mixer and kind == SLSTM:
            return wrap()                     # tiny; replicate

        # MoE MLP
        if in_moe:
            if name == "router":
                return wrap()
            se = _div(E, tp)
            sf = _div(f, tp)
            if name in ("w_gate", "w_up"):
                return wrap(m, None, None) if se else (
                    wrap(None, None, m) if sf else wrap())
            if name == "w_down":
                return wrap(m, None, None) if se else (
                    wrap(None, m, None) if sf else wrap())
        # dense MLP
        if name in ("w_gate", "w_up"):
            return wrap(None, m) if _div(f, tp) else wrap()
        if name == "w_down":
            return wrap(m, None) if _div(f, tp) else wrap()
        return wrap()

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_specs(cfg: ModelConfig, cache, *, model_axis: str = "model",
                data_axis=None, tp: int = 1,
                shard_seq_over_data: bool = False,
                seq_over_model_if_kv_replicated: bool = False):
    """Specs for the KV/state cache pytree (leading period axis on leaves).

    Batch shards over `data_axis`; KV heads / din over `model_axis` when
    divisible; long-context (batch=1) shards the KV sequence over data
    instead (context parallelism). When kv_heads % tp != 0 (MQA/GQA narrower
    than the mesh) the head dim cannot shard — `seq_over_model_if_kv_
    replicated` shards the cache SEQUENCE over the model axis instead
    (flash-decode style), cutting per-chip cache 16x (EXPERIMENTS.md §Perf).
    """
    m = model_axis if tp > 1 else None
    d = data_axis

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v", "cross_k", "cross_v"):
            hkv = leaf.shape[3]
            S = leaf.shape[2]
            hshard = m if (m and hkv % tp == 0) else None
            if shard_seq_over_data:
                return P(None, None, d, hshard, None)
            sshard = None
            if (hshard is None and seq_over_model_if_kv_replicated
                    and m and S % tp == 0):
                sshard = m
            return P(None, d, sshard, hshard, None)
        if name == "conv":
            din = leaf.shape[3]
            return P(None, d, None, m if (m and din % tp == 0) else None)
        if name == "h" and nd == 4:                       # mamba state
            din = leaf.shape[2]
            return P(None, d, m if (m and din % tp == 0) else None, None)
        return P(*([None, d] + [None] * (nd - 2)))

    return jax.tree_util.tree_map_with_path(spec, cache)
