"""Weight-only int8 quantization (beyond-paper; HexGen economics lever:
B_type=1 halves the cost model's memory limits, so the scheduler packs ~2x
the replicas into the same pool — see benchmarks/bench_quant_economics.py).

Per-output-channel symmetric int8: a 2-D+ matmul weight W (..., in, out)
becomes {"q": int8, "s": f32 (out,)}. Dequantization fuses into the matmul
as a post-scale: x @ W ≈ (x @ q) * s, exact for per-out-channel scales.
layers/moe/mamba/xlstm route every weight matmul through `mm()` so the
quantized pytree is a drop-in replacement for the bf16 one.

Quantized leaves keep their Megatron PartitionSpec on "q" and shard "s"
with the output channels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# weight leaf names eligible for quantization (matmul weights only; norms,
# biases, SSM dynamics (A_log, D, dt), conv taps, routers and the embedding
# gather stay full)
QUANT_LEAVES = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj",
    "out_proj", "x_proj", "dt_proj", "lm_head",
    "w_z", "w_i", "w_f", "w_o",
}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def mm(x, w):
    """x @ w for plain or quantized 2-D w (fused dequant post-scale)."""
    if is_quantized(w):
        y = x @ w["q"].astype(x.dtype)
        return y * w["s"].astype(x.dtype)
    return x @ w


def quantize_leaf(w, contract_axis: int = -2):
    """Symmetric int8 with scales over every non-contraction dim: 2-D
    (in, out) -> s (out,); 3-D expert weights (E, in, out) -> s (E, out)."""
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=contract_axis, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": jnp.squeeze(scale, contract_axis).astype(jnp.float32)}


def dequantize_leaf(wq, contract_axis: int = -2):
    s = jnp.expand_dims(wq["s"], contract_axis)
    return wq["q"].astype(jnp.float32) * s


def quantize_params(params, cfg):
    """Quantize every eligible matmul weight in the pytree."""

    def walk(tree, path=()):
        if isinstance(tree, dict) and not is_quantized(tree):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        if name in QUANT_LEAVES and hasattr(tree, "ndim") and tree.ndim >= 2:
            return quantize_leaf(tree)
        return tree

    return walk(params)


def quant_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
