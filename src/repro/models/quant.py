"""Weight-only int8 quantization (beyond-paper; HexGen economics lever:
B_type=1 halves the cost model's memory limits, so the scheduler packs ~2x
the replicas into the same pool — see benchmarks/bench_quant_economics.py).

Per-output-channel symmetric int8: a 2-D+ matmul weight W (..., in, out)
becomes {"q": int8, "s": f32 (out,)}. Dequantization fuses into the matmul
as a post-scale: x @ W ≈ (x @ q) * s, exact for per-out-channel scales.
layers/moe/mamba/xlstm route every weight matmul through `mm()` so the
quantized pytree is a drop-in replacement for the bf16 one.

Quantized leaves keep their Megatron PartitionSpec on "q" and shard "s"
with the output channels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# weight leaf names eligible for quantization (matmul weights only; norms,
# biases, SSM dynamics (A_log, D, dt), conv taps, routers and the embedding
# gather stay full)
QUANT_LEAVES = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj",
    "out_proj", "x_proj", "dt_proj", "lm_head",
    "w_z", "w_i", "w_f", "w_o",
}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def mm(x, w):
    """x @ w for plain or quantized 2-D w (fused dequant post-scale)."""
    if is_quantized(w):
        y = x @ w["q"].astype(x.dtype)
        return y * w["s"].astype(x.dtype)
    return x @ w


def quantize_leaf(w, contract_axis: int = -2):
    """Symmetric int8 with scales over every non-contraction dim: 2-D
    (in, out) -> s (out,); 3-D expert weights (E, in, out) -> s (E, out)."""
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=contract_axis, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": jnp.squeeze(scale, contract_axis).astype(jnp.float32)}


def dequantize_leaf(wq, contract_axis: int = -2):
    s = jnp.expand_dims(wq["s"], contract_axis)
    return wq["q"].astype(jnp.float32) * s


def quantize_params(params, cfg):
    """Quantize every eligible matmul weight in the pytree."""

    def walk(tree, path=()):
        if isinstance(tree, dict) and not is_quantized(tree):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        if name in QUANT_LEAVES and hasattr(tree, "ndim") and tree.ndim >= 2:
            return quantize_leaf(tree)
        return tree

    return walk(params)


def quant_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# KV-cache quantization (paged page pools; docs/memory.md)
#
# Page pools may store K/V at reduced width with PER-TOKEN-PER-HEAD scales:
# a pool (n_blocks, block_size, h_kv, head_dim) grows a float32 companion
# (n_blocks, block_size, h_kv) and every row dequantizes as
# q.astype(f32) * scale[..., None]. Token granularity keeps the single-token
# decode append exact (one .at[blk, off].set per step, no read-modify-write
# of a block statistic) and makes COW / truncate / migration scale handling
# identical to the payload: scales are just another pool leaf addressed by
# the same block ids.
# ---------------------------------------------------------------------------

# kv_dtype name -> (storage dtype, qmax, needs integer rounding).
# fp32/bf16 are the UNQUANTIZED layouts (no scale leaves, pre-PR layout);
# int8/fp8 store scaled payloads. fp8 uses e4m3 (max finite 448): decode
# reads want mantissa, not range — range lives in the scale.
KV_DTYPES = {
    "fp32": (jnp.float32, None, False),
    "bf16": (jnp.bfloat16, None, False),
    "int8": (jnp.int8, 127.0, True),
    "fp8": (jnp.float8_e4m3fn, 448.0, False),
}

KV_SCALE_LEAVES = ("k_scale", "v_scale")


def kv_storage_dtype(kv_dtype: str):
    assert kv_dtype in KV_DTYPES, kv_dtype
    return KV_DTYPES[kv_dtype][0]


def kv_dtype_name(storage_dtype) -> str:
    """Quantized kv_dtype name from a pool payload dtype (int8 -> "int8",
    float8_e4m3fn -> "fp8"); lets write paths infer the scheme from the
    pool itself instead of threading a string everywhere."""
    for name, (dt, qmax, _) in KV_DTYPES.items():
        if qmax is not None and jnp.dtype(storage_dtype) == jnp.dtype(dt):
            return name
    raise ValueError(f"not a quantized KV storage dtype: {storage_dtype}")


def kv_is_quantized(kv_dtype: str) -> bool:
    assert kv_dtype in KV_DTYPES, kv_dtype
    return KV_DTYPES[kv_dtype][1] is not None


def kv_itemsize(kv_dtype: str) -> float:
    """Effective bytes per cache element INCLUDING the per-token-per-head
    scale overhead (4 bytes amortized over head_dim elements is charged by
    callers that know head_dim; this returns the payload width)."""
    return jnp.dtype(kv_storage_dtype(kv_dtype)).itemsize


def quantize_kv_rows(rows, kv_dtype: str):
    """Quantize K or V rows (..., h, d) -> (payload (..., h, d) in the
    storage dtype, scale (..., h) float32). Symmetric per-token-per-head:
    scale = amax over head_dim / qmax."""
    dt, qmax, rnd = KV_DTYPES[kv_dtype]
    assert qmax is not None, kv_dtype
    rf = jnp.asarray(rows, jnp.float32)
    amax = jnp.max(jnp.abs(rf), axis=-1)
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = rf / scale[..., None]
    if rnd:
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    return q.astype(dt), scale.astype(jnp.float32)


def dequantize_kv(payload, scale):
    """Inverse of quantize_kv_rows: payload (..., h, d), scale (..., h)."""
    return payload.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
