"""Shared layer primitives: RMSNorm, RoPE, GQA attention blocks, MLP.

All functions are pure; parameters are plain dict pytrees. Sequence mixing
goes through repro.kernels.ops so the Pallas/XLA backend switch applies
uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.quant import kv_dtype_name, mm, quantize_kv_rows


def rms_norm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope(x, positions, theta):
    """x: (b, s, h, d); positions: (b, s) or (s,). theta==0 disables."""
    if theta == 0.0:
        return x
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (b,s,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d):
    """Whisper-style sinusoidal embeddings. positions (b,s) -> (b,s,d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = mm(x, p["wq"])
    k = mm(x, p["wk"])
    v = mm(x, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def attn_prefill(p, x, cfg, *, positions, kv_start=None, cache=None,
                 window=None):
    """Self-attention over a full (left-padded) prompt.

    positions (b,s) absolute; kv_start (b,) first valid index per row.
    Returns (out, new_cache). cache is written when provided:
      full cache:  {"k": (b,S,hkv,hd), "v": ...} written at [0:s]
      ring cache:  {"k": (b,W,hkv,hd), ...} last W keys
    """
    window = cfg.swa_window if window is None else window
    q, k, v = _qkv(p, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            kv_start=kv_start)
    b, s, _, _ = q.shape
    out = mm(o.reshape(b, s, -1), p["wo"])
    new_cache = None
    if cache is not None:
        if window and cache["k"].shape[1] <= window:
            W = cache["k"].shape[1]
            if s >= W:
                # true ring layout: position p lives at slot p % W, so the
                # decode write at slot pos % W evicts exactly the oldest key
                ck = jnp.roll(k[:, -W:], s % W, axis=1)
                cv = jnp.roll(v[:, -W:], s % W, axis=1)
            else:
                pad = W - s
                ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = {"k": ck.astype(cache["k"].dtype),
                         "v": cv.astype(cache["v"].dtype)}
        else:
            S = cache["k"].shape[1]
            nk = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": nk, "v": nv}
    return out, new_cache


def attn_decode(p, x, cfg, *, pos, kv_start=None, cache=None, window=None):
    """One-token decode. x (b,1,d); pos: scalar int (uniform batch — the
    static-batching and dry-run path, in-place DUS write) or an int32 (b,)
    array of PER-ROW positions (continuous batching — scatter write).

    Full cache: write k,v at [pos], attend to [0:pos+1) minus kv_start pad.
    Ring cache (SWA): write at pos % W, attend to all valid ring slots.
    """
    window = cfg.swa_window if window is None else window
    q, k, v = _qkv(p, x, cfg)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim > 0
    posb = pos[:, None] if per_row else jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    ring = window and cache["k"].shape[1] <= window
    ridx = jnp.arange(b)
    if ring:
        W = cache["k"].shape[1]
        slot = jnp.mod(pos, W)
        if per_row:
            nk = cache["k"].at[ridx, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            nv = cache["v"].at[ridx, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            nk = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        kv_len = jnp.broadcast_to(jnp.minimum(pos + 1, W), (b,))
        o = ops.decode_attention(q, nk, nv, kv_len=kv_len)
    else:
        if per_row:
            nk = cache["k"].at[ridx, pos].set(k[:, 0].astype(cache["k"].dtype))
            nv = cache["v"].at[ridx, pos].set(v[:, 0].astype(cache["v"].dtype))
        else:
            nk = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        kv_len = jnp.broadcast_to(pos + 1, (b,))
        o = ops.decode_attention(q, nk, nv, kv_len=kv_len, kv_start=kv_start)
    out = mm(o.reshape(b, 1, -1), p["wo"])
    return out, {"k": nk, "v": nv}


def attn_decode_paged(p, x, cfg, *, pos, block_tables, cache):
    """One-token decode against a BLOCK-PAGED cache. x (b,1,d); pos (b,)
    per-row absolute positions; block_tables (b, max_blocks) int32;
    cache {"k","v"}: (n_blocks, block_size, hkv, hd) page pools shared by
    every row. The new token's K/V are scattered into the page holding
    position pos (block_tables[i, pos // bs], offset pos % bs) and
    attention gathers through the table (ops.paged_decode_attention).

    Rows whose table is all-null (free slots riding a joint iteration)
    write into the reserved trash page and read garbage that the caller
    discards — exactly like free slots in the contiguous path.

    A QUANTIZED pool (cache carries "k_scale"/"v_scale") quantizes the new
    token's K/V row on write (models/quant.quantize_kv_rows) and hands the
    scale pools to the fused-dequant kernel.
    """
    q, k, v = _qkv(p, x, cfg)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    assert pos.ndim == 1, "paged decode is per-row by construction"
    posb = pos[:, None]
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    bs = cache["k"].shape[1]
    ridx = jnp.arange(b)
    blk = jnp.asarray(block_tables, jnp.int32)[ridx, pos // bs]
    off = pos % bs
    kv_len = pos + 1
    if "k_scale" in cache:
        kvd = kv_dtype_name(cache["k"].dtype)
        kq, ks = quantize_kv_rows(k[:, 0], kvd)
        vq, vs = quantize_kv_rows(v[:, 0], kvd)
        nk = cache["k"].at[blk, off].set(kq)
        nv = cache["v"].at[blk, off].set(vq)
        nks = cache["k_scale"].at[blk, off].set(ks)
        nvs = cache["v_scale"].at[blk, off].set(vs)
        o = ops.paged_decode_attention(q, nk, nv, block_tables,
                                       kv_len=kv_len, k_scale=nks,
                                       v_scale=nvs)
        out = mm(o.reshape(b, 1, -1), p["wo"])
        return out, {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    nk = cache["k"].at[blk, off].set(k[:, 0].astype(cache["k"].dtype))
    nv = cache["v"].at[blk, off].set(v[:, 0].astype(cache["v"].dtype))
    o = ops.paged_decode_attention(q, nk, nv, block_tables, kv_len=kv_len)
    out = mm(o.reshape(b, 1, -1), p["wo"])
    return out, {"k": nk, "v": nv}


def attn_context_paged(p, x, cfg, *, positions, q_len, block_tables, cache):
    """CONTEXT PREFILL against a BLOCK-PAGED cache: x (b,C,d) is a chunk of
    new tokens whose row-i token j sits at absolute position
    positions[i, j] = positions[i, 0] + j; the chunk attends causally to
    the pages holding positions [0, positions[:, 0]) AND to itself. The
    chunk's K/V are scattered into the pages first (same write the decode
    path does, C tokens at once), then attention reads back through the
    table (ops.paged_context_attention) — warm-prefix serving prefills
    only a prompt's cold suffix this way, chunked prefill feeds a long
    prompt through in several such calls.

    q_len (b,): real chunk length per row; padding tokens (j >= q_len)
    scatter into the reserved null page and their outputs are garbage the
    caller discards.
    """
    q, k, v = _qkv(p, x, cfg)
    b, C = x.shape[:2]
    positions = jnp.asarray(positions, jnp.int32)       # (b, C) absolute
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    bs = cache["k"].shape[1]
    tbl = jnp.asarray(block_tables, jnp.int32)
    max_pos = tbl.shape[1] * bs - 1
    valid = jnp.arange(C)[None, :] < jnp.asarray(q_len, jnp.int32)[:, None]
    posc = jnp.minimum(positions, max_pos)              # pad rows stay legal
    blk = jnp.take_along_axis(tbl, posc // bs, axis=1)  # (b, C)
    blk = jnp.where(valid, blk, 0)                      # pads -> null page
    off = posc % bs
    q_start = positions[:, 0]
    kv_len = q_start + jnp.asarray(q_len, jnp.int32)
    if "k_scale" in cache:
        kvd = kv_dtype_name(cache["k"].dtype)
        kq, ks = quantize_kv_rows(k, kvd)
        vq, vs = quantize_kv_rows(v, kvd)
        nk = cache["k"].at[blk, off].set(kq)
        nv = cache["v"].at[blk, off].set(vq)
        nks = cache["k_scale"].at[blk, off].set(ks)
        nvs = cache["v_scale"].at[blk, off].set(vs)
        o = ops.paged_context_attention(q, nk, nv, tbl, q_start=q_start,
                                        kv_len=kv_len, k_scale=nks,
                                        v_scale=nvs)
        out = mm(o.reshape(b, C, -1), p["wo"])
        return out, {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    nk = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype))
    nv = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype))
    o = ops.paged_context_attention(q, nk, nv, tbl, q_start=q_start,
                                    kv_len=kv_len)
    out = mm(o.reshape(b, C, -1), p["wo"])
    return out, {"k": nk, "v": nv}


def attn_verify_paged(p, x, cfg, *, positions, q_len, block_tables, cache):
    """MULTI-TOKEN VERIFICATION against a BLOCK-PAGED cache (speculative
    decoding): x (b,T,d) is each slot's candidate chunk — the bonus token
    plus its draft proposals — whose row-i token j sits at absolute
    position positions[i, j] = positions[i, 0] + j, the slot's committed
    KV length. The chunk's K/V scatter into the pages first (the same
    write the decode path does, T tokens at once), then every candidate
    attends causally to the committed pages AND the candidate prefix
    through the per-slot-start verification kernel
    (ops.paged_verify_attention). The caller keeps the output at EVERY
    position: acceptance compares the target's next-token choice after
    each candidate against the next candidate.

    q_len (b,): real candidate count per row; rows with q_len == 0 (free /
    mid-prefill slots riding the joint dispatch) scatter into the reserved
    null page and come back dead. Rolling back REJECTED candidates is the
    caller's job (BlockTable.truncate) — their stale page writes sit past
    the committed length, masked by kv_len, and are overwritten by the
    next verification chunk.
    """
    q, k, v = _qkv(p, x, cfg)
    b, T = x.shape[:2]
    positions = jnp.asarray(positions, jnp.int32)       # (b, T) absolute
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    bs = cache["k"].shape[1]
    tbl = jnp.asarray(block_tables, jnp.int32)
    max_pos = tbl.shape[1] * bs - 1
    valid = jnp.arange(T)[None, :] < jnp.asarray(q_len, jnp.int32)[:, None]
    posc = jnp.minimum(positions, max_pos)              # pad rows stay legal
    blk = jnp.take_along_axis(tbl, posc // bs, axis=1)  # (b, T)
    blk = jnp.where(valid, blk, 0)                      # pads -> null page
    off = posc % bs
    kv_start = positions[:, 0]
    kv_len = kv_start + jnp.asarray(q_len, jnp.int32)
    if "k_scale" in cache:
        kvd = kv_dtype_name(cache["k"].dtype)
        kq, ks = quantize_kv_rows(k, kvd)
        vq, vs = quantize_kv_rows(v, kvd)
        nk = cache["k"].at[blk, off].set(kq)
        nv = cache["v"].at[blk, off].set(vq)
        nks = cache["k_scale"].at[blk, off].set(ks)
        nvs = cache["v_scale"].at[blk, off].set(vs)
        o = ops.paged_verify_attention(q, nk, nv, tbl, kv_start=kv_start,
                                       kv_len=kv_len, k_scale=nks,
                                       v_scale=nvs)
        out = mm(o.reshape(b, T, -1), p["wo"])
        return out, {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    nk = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype))
    nv = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype))
    o = ops.paged_verify_attention(q, nk, nv, tbl, kv_start=kv_start,
                                   kv_len=kv_len)
    out = mm(o.reshape(b, T, -1), p["wo"])
    return out, {"k": nk, "v": nv}


def cross_attn(p, x, cfg, *, enc_kv=None, enc_out=None):
    """Whisper cross-attention. enc_kv: precomputed {"k","v"} over encoder
    frames (cached at prefill); or compute from enc_out."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = mm(x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    if enc_kv is None:
        se = enc_out.shape[1]
        k = mm(enc_out, p["wk"]).reshape(b, se, cfg.num_kv_heads, hd)
        v = mm(enc_out, p["wv"]).reshape(b, se, cfg.num_kv_heads, hd)
        enc_kv = {"k": k, "v": v}
    o = ops.flash_attention(q, enc_kv["k"].astype(q.dtype),
                            enc_kv["v"].astype(q.dtype), causal=False)
    return mm(o.reshape(b, s, -1), p["wo"]), enc_kv


def attn_encoder(p, x, cfg):
    """Bidirectional self-attention (whisper encoder)."""
    q, k, v = _qkv(p, x, cfg)
    o = ops.flash_attention(q, k, v, causal=False)
    b, s = x.shape[:2]
    return mm(o.reshape(b, s, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(p, x, cfg):
    if cfg.activation == "silu":
        return mm(jax.nn.silu(mm(x, p["w_gate"])) * mm(x, p["w_up"]),
                  p["w_down"])
    return mm(jax.nn.gelu(mm(x, p["w_up"])), p["w_down"])
