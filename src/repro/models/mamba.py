"""Mamba (S6) selective-SSM block, Jamba-style.

Prefill/train use the chunked parallel scan in kernels.ops (Pallas on TPU);
decode is a single-step state update. TP sharding follows the Megatron
pattern: in_proj column-parallel over d_inner, out_proj row-parallel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.quant import mm


def _dt_rank(cfg):
    return cfg.ssm_dt_rank or -(-cfg.d_model // 16)


def d_inner(cfg):
    return cfg.ssm_expand * cfg.d_model


def _project(p, x, cfg, valid=None):
    """Shared pre-scan computation. x (b,s,d) -> xz pieces + dt/B/C."""
    xz = mm(x, p["in_proj"])                             # (b,s,2*din)
    xi, z = jnp.split(xz, 2, axis=-1)
    return xi, z


def _ssm_inputs(p, xi, cfg, valid=None):
    dtr = _dt_rank(cfg)
    ds = cfg.ssm_d_state
    dbc = mm(xi, p["x_proj"])                            # (b,s,dtr+2ds)
    dt_raw = dbc[..., :dtr]
    B = dbc[..., dtr:dtr + ds]
    C = dbc[..., dtr + ds:]
    dt = jax.nn.softplus(mm(dt_raw, p["dt_proj"]) + p["dt_bias"])
    if valid is not None:
        dt = dt * valid[..., None].astype(dt.dtype)   # pad steps = identity
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    return dt, A, B, C


def mamba_prefill(p, x, cfg, *, valid=None, lens=None, cache=None):
    """x (b,s,d); valid (b,s) 0/1 mask for padded rows (pad steps become
    identity state updates). lens (b,) marks RIGHT padding (slot insertion):
    the conv cache tail must then be each row's last `w-1` REAL inputs, not
    the trailing pads. Returns (out, new_cache) where
    cache = {"conv": (b,w-1,din), "h": (b,din,ds)}."""
    b, s, _ = x.shape
    xi, z = _project(p, x, cfg)
    if valid is not None:
        xi = xi * valid[..., None].astype(xi.dtype)
    # causal depthwise conv1d, width w
    w = cfg.ssm_d_conv
    xpad = jnp.pad(xi, ((0, 0), (w - 1, 0), (0, 0)))
    xc = _depthwise_conv(xpad, p["conv_w"], p["conv_b"])   # (b,s,din)
    xc = jax.nn.silu(xc)
    dt, A, B, C = _ssm_inputs(p, xc, cfg, valid=valid)
    y, h = ops.ssm_scan(xc, dt, A, B, C, p["D"])
    out = mm(y * jax.nn.silu(z), p["out_proj"])
    new_cache = None
    if cache is not None:
        if w <= 1:
            conv_tail = xpad[:, :0]
        elif lens is not None:
            # row i's real inputs sit at xpad[(w-1)+j], j < lens[i]; the tail
            # [lens[i], lens[i]+w-1) spans its last real inputs plus the
            # conv's implicit leading zeros when lens[i] < w-1.
            idx = lens[:, None] + jnp.arange(w - 1)[None, :]
            conv_tail = jnp.take_along_axis(xpad, idx[..., None], axis=1)
        else:
            conv_tail = xpad[:, -(w - 1):]
        new_cache = {"conv": conv_tail.astype(cache["conv"].dtype),
                     "h": h.astype(cache["h"].dtype)}
    return out, new_cache


def mamba_decode(p, x, cfg, *, cache):
    """x (b,1,d). cache {"conv": (b,w-1,din), "h": (b,din,ds)}."""
    b = x.shape[0]
    w = cfg.ssm_d_conv
    xi, z = _project(p, x, cfg)                       # (b,1,din)
    hist = jnp.concatenate(
        [cache["conv"].astype(xi.dtype), xi], axis=1)  # (b,w,din)
    kern = p["conv_w"]                                # (din,w)
    xc = jnp.einsum("bwd,dw->bd", hist, kern) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]                  # (b,1,din)
    dt, A, B, C = _ssm_inputs(p, xc, cfg)
    y, h = ops.ssm_step(xc[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], p["D"],
                        cache["h"].astype(jnp.float32))
    out = mm(y[:, None, :] * jax.nn.silu(z), p["out_proj"])
    new_cache = {"conv": hist[:, 1:].astype(cache["conv"].dtype),
                 "h": h.astype(cache["h"].dtype)}
    return out, new_cache


def _depthwise_conv(xpad, kern, bias):
    """xpad (b, s+w-1, din); kern (din, w) -> (b, s, din) causal."""
    w = kern.shape[-1]
    s = xpad.shape[1] - (w - 1)
    # unrolled taps: w is tiny (4)
    out = jnp.zeros((xpad.shape[0], s, xpad.shape[2]), xpad.dtype)
    for i in range(w):
        out = out + xpad[:, i:i + s] * kern[:, i][None, None, :]
    return out + bias[None, None, :]
