"""Mixture-of-Experts MLP: top-k router + capacity-buffer dispatch.

Two dispatch strategies (MOE_DISPATCH module flag):

"grouped" (default; §Perf hillclimb in EXPERIMENTS.md): ranking and
capacity are computed PER BATCH ROW, so the (row, expert, capacity, d)
dispatch buffers inherit the batch's data-axis sharding and the rank cumsum
never crosses shards. Expert matmuls run as one batched einsum; under the
production mesh the only collective left is the row-parallel psum of the
d_ff-sharded second projection (or the expert-sharded all-to-all when
num_experts % tp == 0).

"global" (the naive baseline kept for the before/after measurement): one
global rank cumsum over all tokens and globally-indexed buffers — GSPMD
materializes cross-shard all-gathers/all-reduces for the scatter (the
collective-bound pathology in EXPERIMENTS.md §Perf).

Both drop overflowing tokens (combine weight 0) per capacity-factor
semantics, and both switch to dropless capacity for small token counts
(decode): per-row dropless needs only C = s slots since an expert appears at
most once in a token's top-k.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import parallel_ctx as ctx
from repro.models import quant

MOE_DISPATCH = "grouped"            # "grouped" | "global"

# jax.shard_map landed after the experimental namespace; the replication
# check flag was also renamed check_rep -> check_vma along the way.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                               # older jax (e.g. 0.4.x)
    from jax.experimental.shard_map import shard_map as _shard_map
_SM_CHECK_KW = next(
    (k for k in ("check_vma", "check_rep")
     if k in inspect.signature(_shard_map).parameters), None)


def moe_mlp(p, x, cfg, *, return_aux=False):
    if MOE_DISPATCH == "grouped" and ctx.active():
        return _moe_shard_map(p, x, cfg, return_aux=return_aux)
    if MOE_DISPATCH == "grouped":
        return _moe_grouped(p, x, cfg, return_aux=return_aux)
    return _moe_global(p, x, cfg, return_aux=return_aux)


# ---------------------------------------------------------------------------
# shard_map path (production mesh): dispatch and combine are SHARD-LOCAL by
# construction; the only collective is one psum(model) of the combined
# (b_local, s, d) activations per layer — the same pattern as a Megatron
# row-parallel MLP. Two expert-weight layouts:
#   E % tp == 0: expert-parallel — each model shard owns E/tp experts and
#                computes only its experts' contributions (partial over the
#                token's top-k set), summed by the psum;
#   else:        d_ff-parallel — every shard holds all experts with an f
#                slice; outputs are partial over f, summed by the psum.
# ---------------------------------------------------------------------------

def _moe_shard_map(p, x, cfg, *, return_aux):
    mesh = ctx.MESH
    model_ax = ctx.MODEL_AXIS
    data_axes = ctx.DATA_AXES
    tp = 1
    n_data = 1
    for n, sz in zip(mesh.axis_names, mesh.devices.shape):
        if n == model_ax:
            tp = sz
        if n in data_axes:
            n_data *= sz
    if x.shape[0] % n_data:
        data_axes = ()                 # tiny decode batch: replicate rows
    E, f = cfg.num_experts, cfg.d_ff
    expert_parallel = tp > 1 and E % tp == 0
    f_parallel = tp > 1 and not expert_parallel and f % tp == 0

    if expert_parallel:
        wspec = {"router": P(), "w_gate": P(model_ax, None, None),
                 "w_up": P(model_ax, None, None),
                 "w_down": P(model_ax, None, None)}
    elif f_parallel:
        wspec = {"router": P(), "w_gate": P(None, None, model_ax),
                 "w_up": P(None, None, model_ax),
                 "w_down": P(None, model_ax, None)}
    else:
        wspec = {k: P() for k in ("router", "w_gate", "w_up", "w_down")}
    wspec = {k: wspec[k] for k in p}           # align key order/presence
    xspec = P(data_axes if data_axes else None, None, None)

    def local(pl, xl):
        out, aux = _moe_local(pl, xl, cfg,
                              expert_offset_axis=(model_ax if expert_parallel
                                                  else None),
                              tp=tp if expert_parallel else 1)
        if tp > 1:
            out = jax.lax.psum(out, model_ax)
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        if tp > 1:
            aux = jax.lax.pmean(aux, model_ax) if not expert_parallel else \
                jax.lax.psum(aux, model_ax)
        return out, aux

    smkw = {_SM_CHECK_KW: False} if _SM_CHECK_KW else {}
    out, aux = _shard_map(
        local, mesh=mesh, in_specs=(wspec, xspec),
        out_specs=(xspec, P()), **smkw)(p, x)
    if return_aux:
        return out, aux
    return out


def _moe_local(p, x, cfg, *, expert_offset_axis, tp):
    """Per-shard grouped dispatch. With expert parallelism the shard owns
    experts [idx*E_loc, (idx+1)*E_loc) and drops other assignments (their
    contributions come from sibling shards via the psum)."""
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    E_loc = E // tp

    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if s * k <= 64:
        C = s
    else:
        C = max(int(cfg.capacity_factor * s * k / E), 1)

    fe = expert_ids.reshape(b, s * k)
    fg = gate_vals.reshape(b, s * k)

    if expert_offset_axis is not None:
        shard = jax.lax.axis_index(expert_offset_axis)
        fe_loc = fe - shard * E_loc
        owned = (fe_loc >= 0) & (fe_loc < E_loc)
    else:
        fe_loc = fe
        owned = jnp.ones_like(fe, bool)

    onehot = jax.nn.one_hot(jnp.where(owned, fe_loc, E_loc), E_loc,
                            dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.where(owned, jnp.take_along_axis(
        ranks, jnp.clip(fe_loc, 0, E_loc - 1)[:, :, None], axis=2)[..., 0],
        C)
    keep = owned & (rank < C)
    slot = jnp.where(keep, fe_loc * C + rank, E_loc * C)

    src = jnp.repeat(x, k, axis=1)
    bidx = jnp.arange(b)[:, None]
    buf = jnp.zeros((b, E_loc * C + 1, d), x.dtype).at[bidx, slot].set(src)
    buf = buf[:, :E_loc * C].reshape(b, E_loc, C, d)

    out_buf = _expert_ffn(p, buf, cfg)
    flat = jnp.concatenate(
        [out_buf.reshape(b, E_loc * C, d),
         jnp.zeros((b, 1, d), out_buf.dtype)], axis=1)
    gathered = flat[bidx, slot]
    w = (fg * keep).astype(x.dtype)
    out = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    aux = _aux_loss(cfg, probs, fe, b * s * k)
    if expert_offset_axis is not None:
        aux = aux / tp                 # psum over shards reassembles it
    return out, aux


def _eins(buf, w, eq):
    """Expert einsum for plain or int8 w ({"q","s"}, s per (E, out))."""
    if quant.is_quantized(w):
        y = jnp.einsum(eq, buf, w["q"].astype(buf.dtype))
        s = w["s"].astype(buf.dtype)          # (E, out)
        return y * s[:, None, :]
    return jnp.einsum(eq, buf, w)


def _expert_ffn(p, buf, cfg):
    """buf (..., C, d) batched over the expert axis E."""
    if cfg.activation == "silu":
        hidden = jax.nn.silu(_eins(buf, p["w_gate"], "...ecd,edf->...ecf")) \
            * _eins(buf, p["w_up"], "...ecd,edf->...ecf")
    else:
        hidden = jax.nn.gelu(_eins(buf, p["w_up"], "...ecd,edf->...ecf"))
    return _eins(hidden, p["w_down"], "...ecf,efd->...ecd")


def _aux_loss(cfg, probs, flat_expert, denom):
    E = cfg.num_experts
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    ce = jnp.zeros(E).at[flat_expert.reshape(-1)].add(1.0) / denom
    return E * jnp.sum(me * ce) * cfg.router_aux_coef


def _moe_grouped(p, x, cfg, *, return_aux):
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.top_k

    logits = (x @ p["router"]).astype(jnp.float32)               # (b,s,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (b,s,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if s * k <= 64:
        C = s                      # per-row dropless (decode)
    else:
        C = max(int(cfg.capacity_factor * s * k / E), 1)

    fe = expert_ids.reshape(b, s * k)                            # (b,sk)
    fg = gate_vals.reshape(b, s * k)
    onehot = jax.nn.one_hot(fe, E, dtype=jnp.int32)              # (b,sk,E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                  # row-local
    rank = jnp.take_along_axis(ranks, fe[:, :, None], axis=2)[..., 0]
    keep = rank < C
    slot = jnp.where(keep, fe * C + rank, E * C)                 # (b,sk)

    src = jnp.repeat(x, k, axis=1)                               # (b,sk,d)
    bidx = jnp.arange(b)[:, None]
    buf = jnp.zeros((b, E * C + 1, d), x.dtype).at[bidx, slot].set(src)
    buf = buf[:, :E * C].reshape(b, E, C, d)

    out_buf = _expert_ffn(p, buf, cfg)                           # (b,E,C,d)
    flat = jnp.concatenate(
        [out_buf.reshape(b, E * C, d),
         jnp.zeros((b, 1, d), out_buf.dtype)], axis=1)
    gathered = flat[bidx, slot]                                  # (b,sk,d)
    w = (fg * keep).astype(x.dtype)
    out = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    if return_aux:
        return out, _aux_loss(cfg, probs, fe, b * s * k)
    return out


def _moe_global(p, x, cfg, *, return_aux):
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = b * s
    xt = x.reshape(T, d)

    logits = (xt @ p["router"]).astype(jnp.float32)              # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if T <= 8192:
        C = T                      # dropless
    else:
        C = max(int(cfg.capacity_factor * T * k / E), 1)

    flat_expert = expert_ids.reshape(T * k)
    flat_gate = gate_vals.reshape(T * k)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot                  # GLOBAL
    rank = jnp.take_along_axis(ranks, flat_expert[:, None], axis=1)[:, 0]
    keep = rank < C
    slot = jnp.where(keep, flat_expert * C + rank, E * C)

    src = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(src)
    buf = buf[:E * C].reshape(E, C, d)

    expert_out = _expert_ffn(p, buf, cfg)
    flat_out = jnp.concatenate(
        [expert_out.reshape(E * C, d), jnp.zeros((1, d), expert_out.dtype)],
        axis=0)
    gathered = flat_out[slot]
    w = (flat_gate * keep).astype(x.dtype)
    out = (gathered * w[:, None]).reshape(T, k, d).sum(axis=1).reshape(
        b, s, d)
    if return_aux:
        return out, _aux_loss(cfg, probs, flat_expert, T * k)
    return out
