"""xLSTM blocks: mLSTM (matrix memory, chunked linear attention) and sLSTM
(scalar memory, sequential recurrence with exponential gating + stabilizer).

mLSTM gating uses sigmoid i/f (softened vs the paper's exp input gate) so the
chunked-parallel form stays numerically bounded -- see DESIGN.md §3.
sLSTM keeps the paper's exponential gating with the m_t stabilizer since it is
a sequential scan anyway. sLSTM recurrent matrices are block-diagonal per
head (head-parallel under TP; no intra-timestep collective).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.quant import mm


def m_d_inner(cfg):
    return cfg.ssm_expand * cfg.d_model


def m_qk_dim(cfg):
    return int(m_d_inner(cfg) * cfg.xlstm_qk_dim_factor)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_qkv_gates(p, x, cfg, valid=None):
    b, s, _ = x.shape
    h = cfg.num_heads
    din = m_d_inner(cfg)
    qk = m_qk_dim(cfg)
    up = mm(x, p["w_up"])                                 # (b,s,2*din)
    xi, z = jnp.split(up, 2, axis=-1)
    q = mm(xi, p["wq"]).reshape(b, s, h, qk // h)
    k = mm(xi, p["wk"]).reshape(b, s, h, qk // h)
    v = mm(xi, p["wv"]).reshape(b, s, h, din // h)
    ig = jax.nn.sigmoid(mm(xi, p["w_i"]))              # (b,s,h)
    fg = jax.nn.sigmoid(mm(xi, p["w_f"]))
    if valid is not None:
        vm = valid.astype(ig.dtype)[..., None]
        ig = ig * vm                                   # pad: i=0
        fg = fg * vm + (1.0 - vm)                      # pad: f=1 (identity)
    return q, k, v, ig, fg, z


def mlstm_prefill(p, x, cfg, *, valid=None, cache=None):
    q, k, v, ig, fg, z = _mlstm_qkv_gates(p, x, cfg, valid)
    C0 = n0 = None
    if cache is not None:
        C0, n0 = cache["C"], cache["n"]
    y, (C, n) = ops.mlstm_scan(q, k, v, ig, fg, C0=C0, n0=n0)
    b, s = x.shape[:2]
    y = y.reshape(b, s, -1)
    out = mm(y * jax.nn.silu(z), p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"C": C.astype(cache["C"].dtype),
                     "n": n.astype(cache["n"].dtype)}
    return out, new_cache


def mlstm_decode(p, x, cfg, *, cache):
    q, k, v, ig, fg, z = _mlstm_qkv_gates(p, x, cfg)
    y, (C, n) = ops.mlstm_step(
        q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0],
        cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32))
    b = x.shape[0]
    out = mm(y.reshape(b, 1, -1) * jax.nn.silu(z), p["out_proj"])
    return out, {"C": C.astype(cache["C"].dtype),
                 "n": n.astype(cache["n"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_gates(p, x_t, h_prev, cfg):
    """Per-step gate preactivations. x_t (b,d); h_prev (b,d).
    Recurrent weights are block-diagonal per head: r_* (heads, dh, dh)."""
    b = x_t.shape[0]
    heads = cfg.num_heads
    d = cfg.d_model
    dh = d // heads
    hp = h_prev.reshape(b, heads, dh)

    def rec(name):
        return jnp.einsum("bhk,hkj->bhj", hp, p[name]).reshape(b, d)

    zi = mm(x_t, p["w_z"]) + rec("r_z") + p["b_z"]
    ii = mm(x_t, p["w_i"]) + rec("r_i") + p["b_i"]
    ff = mm(x_t, p["w_f"]) + rec("r_f") + p["b_f"]
    oo = mm(x_t, p["w_o"]) + rec("r_o") + p["b_o"]
    return zi, ii, ff, oo


def _slstm_step_pre(p, g_t, state, cfg):
    """One step given precomputed input projections g_t = {z,i,f,o: (b,d)}."""
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    b, d = h.shape
    heads = cfg.num_heads
    hp = h.astype(g_t["z"].dtype).reshape(b, heads, d // heads)

    def rec(name):
        return jnp.einsum("bhk,hkj->bhj", hp, p[name]).reshape(b, d)

    zi = g_t["z"] + rec("r_z")
    ii = g_t["i"] + rec("r_i")
    ff = g_t["f"] + rec("r_f")
    oo = g_t["o"] + rec("r_o")
    return _slstm_core(zi, ii, ff, oo, c, n, m)


def _slstm_core(zi, ii, ff, oo, c, n, m):
    zi, ii, ff, oo = (t.astype(jnp.float32) for t in (zi, ii, ff, oo))
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oo)
    logf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(logf + m, ii)
    i_s = jnp.exp(ii - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "m": m_new, "h": h_new}


def slstm_step(p, x_t, state, cfg):
    """One sLSTM step with exponential gating + stabilizer.
    state: dict c,n,m,h each (b,d) float32."""
    c, n, m, h = state["c"], state["n"], state["m"], state["h"]
    zi, ii, ff, oo = slstm_gates(p, x_t, h.astype(x_t.dtype), cfg)
    zi, ii, ff, oo = (t.astype(jnp.float32) for t in (zi, ii, ff, oo))
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oo)
    logf = jax.nn.log_sigmoid(ff)                      # exp-gate via sigmoid form
    m_new = jnp.maximum(logf + m, ii)
    i_s = jnp.exp(ii - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "m": m_new, "h": h_new}


def slstm_prefill(p, x, cfg, *, valid=None, cache=None):
    """Sequential scan over the sequence. x (b,s,d).

    The input-side gate projections W_g x (4 gates x d^2 weights) are
    hoisted OUT of the scan as one batched matmul, so the recurrence only
    reads the precomputed (b,s,d) gate streams and the tiny per-head
    recurrent blocks -- the weight matrices stream from HBM once instead of
    once per timestep (EXPERIMENTS.md §Perf iteration 4)."""
    b, s, d = x.shape
    state = cache if cache is not None else slstm_init_state(b, d)
    state = {k: v.astype(jnp.float32) for k, v in state.items()}

    # hoisted input projections: (b, s, d) per gate
    gx = {g: mm(x, p[f"w_{g}"]) + p[f"b_{g}"] for g in ("z", "i", "f", "o")}

    def step(state, inp):
        if valid is not None:
            g_t, v_t = inp
        else:
            g_t, v_t = inp, None
        new = _slstm_step_pre(p, g_t, state, cfg)
        if v_t is not None:
            vm = v_t.astype(jnp.float32)[:, None]
            new = {k: vm * new[k] + (1 - vm) * state[k] for k in state}
        return new, new["h"]

    xs = {g: jnp.moveaxis(t, 1, 0) for g, t in gx.items()}
    if valid is not None:
        xs = (xs, jnp.moveaxis(valid, 1, 0))
    state, hs = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # (b,s,d)
    out = mm(y, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {k: state[k].astype(cache[k].dtype) for k in state}
    return out, new_cache


def slstm_decode(p, x, cfg, *, cache):
    state = {k: v.astype(jnp.float32) for k, v in cache.items()}
    new = slstm_step(p, x[:, 0], state, cfg)
    out = mm(new["h"].astype(x.dtype)[:, None, :], p["out_proj"])
    return out, {k: new[k].astype(cache[k].dtype) for k in cache}


def slstm_init_state(b, d):
    z = jnp.zeros((b, d), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}
