"""Disaggregated prefill/decode serving: KV handoff between replicas.

HexGen serves each request on ONE asymmetric pipeline; its successor
(HexGen-2, cf. DistServe/Splitwise) splits the two inference phases across
replicas — prefill runs on compute-rich replicas, decode on memory-rich
ones — because the phases want opposite hardware: prefill is a
compute-bound burst over the whole prompt, decode is a memory-bandwidth
drip that monopolizes KV capacity. Colocating them makes long prefills
stall every in-flight decode (TTFT/TPOT interference); splitting them
turns the interference into an explicit, schedulable NETWORK transfer.

The paged KV subsystem makes that transfer cheap to express: a finished
prefill's cache is a set of fixed-size pages plus a block table, so the
handoff is "gather the pages, ship the bytes, scatter them into the
destination pool and hand over the table" — not a cache-layout rewrite.

This module holds the host-side pieces:

  * ``KVMigration`` — the wire format: per-LAYER page payloads (keyed by
    global layer so source and destination pipelines may split stages
    differently), the cached token count, and the sampling state (last
    prefill logits) the decode replica resumes from.
  * ``KVLink``     — the transfer model: ``delay(bytes, src, dst)`` on the
    serving clock, either a flat gigabit figure (``--kv-link-gbps``) or
    per-replica-pair alpha-beta costs from ``core.cluster`` matrices.
  * ``KVDispatcher`` — picks the decode replica by queue depth and delivers
    the migration at ``now + delay``.

Engine-side mechanics (extract/scatter, slot resume) live in
``serving.pipeline`` and ``serving.continuous``; the scheduler-side role
search lives in ``core.genetic`` / ``core.slo_sim``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import NULL_TRACER

GBPS = 1e9 / 8.0                   # bytes per second per Gbit/s


@dataclasses.dataclass
class KVMigration:
    """One finished prefill, packaged for the wire.

    ``layer_kv[l]`` holds global layer l's page payload ``{"k", "v"}`` of
    shape (n_blocks, block_size, kv_heads, head_dim) — whole blocks, the
    partial tail block travelling with its (masked, never-read) garbage.
    ``last_logits`` is the prefill's last-position logits: greedy decode on
    the destination argmaxes exactly what the colocated engine would have,
    so the handoff is bit-invisible to the token stream.

    ``out_tokens`` rides along for MID-DECODE migrations (the online
    rescheduler moving a live slot between layouts): the tokens the source
    already emitted, so the destination resumes the stream mid-flight
    instead of restarting it. ``n_tokens`` then counts prompt + emitted
    tokens resident in the pages. None for the ordinary prefill handoff.
    """

    req: object                    # serving.request.Request
    n_tokens: int                  # prompt tokens resident in the pages
    block_size: int
    layer_kv: List[Dict[str, np.ndarray]]
    last_logits: np.ndarray        # (vocab,) float32 sampling state
    kv_bytes: int                  # payload size, drives the transfer model
    out_tokens: Optional[np.ndarray] = None   # emitted tokens (live move)

    @staticmethod
    def payload_bytes(layer_kv: Sequence[Dict[str, np.ndarray]]) -> int:
        return int(sum(a.nbytes for lkv in layer_kv for a in lkv.values()))


class KVLink:
    """Transfer-time model for KV handoffs, in serving-clock units.

    Flat mode (``KVLink(gbps=...)``) charges ``bytes / bandwidth`` plus a
    fixed latency for every pair — the ``--kv-link-gbps`` surface knob;
    ``gbps=0`` means an ideal (instantaneous) interconnect, the right
    default for bit-identity smokes. ``from_cluster`` derives PER-PAIR
    alpha-beta costs from the pool's comm matrices: the transfer takes the
    best link between the source replica's last stage and the destination
    replica's first stage, exactly like the cost model's pipeline-comm
    term (cost_model.comm_pp_cost).
    """

    def __init__(self, gbps: float = 0.0, latency: float = 0.0):
        self.bandwidth = gbps * GBPS if gbps > 0 else float("inf")
        self.latency = latency
        self._pairs: Optional[Dict] = None   # (src, dst) -> (lat, bw)

    @classmethod
    def from_cluster(cls, cluster, replica_devices: Sequence[Sequence[int]],
                     src_stage_devices: Optional[Sequence[Sequence[int]]]
                     = None,
                     dst_stage_devices: Optional[Sequence[Sequence[int]]]
                     = None) -> "KVLink":
        """Per-pair link costs from ``core.cluster.Cluster`` matrices.

        ``replica_devices[i]`` are replica i's global device ids (used for
        both endpoints unless the finer-grained ``src_stage_devices`` /
        ``dst_stage_devices`` — last-stage and first-stage ids — are
        given)."""
        link = cls()
        src = (list(src_stage_devices) if src_stage_devices is not None
               else [list(d) for d in replica_devices])
        dst = (list(dst_stage_devices) if dst_stage_devices is not None
               else [list(d) for d in replica_devices])
        pairs = {}
        for i, sd in enumerate(src):
            for j, dd in enumerate(dst):
                if i == j:
                    continue
                # keep every Pareto-optimal (lat, bw) candidate: which
                # link is best depends on the payload size, so the winner
                # is chosen per transfer in delay() — exactly the
                # min(lat + bytes/bw) criterion the scheduler's role
                # search scores with (genetic.Evaluator._pair_delay_fn)
                cands = sorted({(float(cluster.lat[a, b]),
                                 float(cluster.bw[a, b]))
                                for a in sd for b in dd})
                pareto = []
                best_bw = -1.0
                for lat, bw in cands:          # lat ascending
                    if bw > best_bw:
                        pareto.append((lat, bw))
                        best_bw = bw
                pairs[(i, j)] = pareto
        link._pairs = pairs
        return link

    def delay(self, n_bytes: int, src: int = 0, dst: int = 0) -> float:
        if self._pairs is not None:
            return min(lat + (n_bytes / bw if np.isfinite(bw) else 0.0)
                       for lat, bw in self._pairs[(src, dst)])
        xfer = n_bytes / self.bandwidth if np.isfinite(self.bandwidth) \
            else 0.0
        return self.latency + xfer


class KVDispatcher:
    """Routes finished prefills to decode replicas.

    The destination is the decode replica with the smallest queue depth
    (resident + queued + in-transit migrations — each worker's ``load``),
    mirroring the router's least-loaded arrival dispatch one phase later.
    """

    def __init__(self, targets: Sequence, link: Optional[KVLink] = None):
        assert targets, "disaggregation needs at least one decode replica"
        self.targets = list(targets)
        self.link = link if link is not None else KVLink()
        self.tracer = NULL_TRACER      # Router.serve swaps in the live one

    def send(self, src, mig: KVMigration, now: float) -> float:
        """Deliver `mig` to the least-loaded decode replica; returns the
        arrival (ready) time on the serving clock."""
        dst = min(self.targets, key=lambda w: (w.load(now), w.replica_id))
        delay = self.link.delay(mig.kv_bytes,
                                getattr(src, "replica_id", 0),
                                dst.replica_id)
        ready = now + delay
        if self.tracer.enabled:
            self.tracer.complete("kv_migration", delay, ts=now,
                                 pid=getattr(src, "replica_id", 0),
                                 rid=mig.req.rid, dst=dst.replica_id,
                                 bytes=mig.kv_bytes)
        dst.migrate_in(mig, ready)
        return ready


def wire_disaggregation(workers: Sequence, roles: Sequence[str],
                        link: Optional[KVLink] = None) -> Optional[KVDispatcher]:
    """Attach a shared KVDispatcher to every prefill worker, targeting the
    decode workers. Roles: "prefill" | "decode" | "both"; all-"both" is
    colocated serving and returns None. Used by the Router and directly by
    benches/tests that build workers by hand."""
    assert len(workers) == len(roles)
    for i, w in enumerate(workers):
        w.replica_id = i
    if all(r == "both" for r in roles):
        return None
    prefills = [w for w, r in zip(workers, roles) if r == "prefill"]
    decodes = [w for w, r in zip(workers, roles) if r == "decode"]
    assert prefills and decodes, \
        f"disaggregation needs >=1 prefill and >=1 decode replica: {roles}"
    disp = KVDispatcher(decodes, link)
    for w in prefills:
        w.dispatcher = disp
    return disp
