"""The one serving loop (beyond-paper substrate for iteration-level
scheduling over heterogeneous replicas, cf. HexGen-2 / Helix).

Every serving path in the repo — the multi-replica Router, the
single-replica continuous batcher, and the analytic SLO simulator — drives
the same event loop with the same admission policy and the same accounting.
The loop is event-driven at ITERATION granularity: each cycle it (1) admits
due arrivals one at a time onto the least-loaded worker with capacity,
(2) runs one iteration on every busy worker, and (3) when nothing is
runnable, advances the clock to the next event (arrival or completion).

Time is pluggable:

  * ``WallClock``   — real time; idle waits sleep. Benchmarks and live
    serving.
  * ``VirtualClock`` — deterministic simulated time; idle waits jump, and
    each worker iteration advances time by the worker's reported cost.
    Tests and the analytic SLO simulator (identical workload in → identical
    ``ServeStats`` out, bit for bit).

Workers duck-type the replica port below. A worker may be a real engine
(slot-based continuous batcher over a monolithic model or an asymmetric
pipeline), a static whole-batch engine, or a closed-form analytic model:

  capacity(now) -> int        admissible request count right now
  load(now) -> float          least-loaded dispatch key (lower = preferred)
  admit(reqs, now) -> None    hand over requests (may buffer internally)
  busy(now) -> bool           has runnable work at `now`
  run_iteration(now) -> (completions, cost)
                              one iteration; completions are
                              (request, output | None, finish_time | None)
                              tuples — finish_time None means "stamp with
                              the clock after this iteration"; cost is the
                              virtual-clock advance for the iteration
  next_event(now) -> float | None
                              earliest future event when idle (analytic
                              completions, etc.); None if none
  inflight() -> int           admitted but unfinished request count
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.obs.trace import NULL_TRACER


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

class WallClock:
    """Monotonic wall time, zeroed at construction. Idle waits sleep."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)

    def tick(self, cost: float) -> None:
        pass                       # real work advanced real time already


class VirtualClock:
    """Deterministic simulated time. Idle waits jump; iterations advance by
    the worker-reported cost."""

    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def sleep_until(self, t: float) -> None:
        self._t = max(self._t, t)

    def tick(self, cost: float) -> None:
        self._t += cost


# ---------------------------------------------------------------------------
# Accounting — the single ServeStats path
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeStats:
    latencies: List[float]
    attainment: float
    throughput: float
    iterations: int = 0            # worker iterations the loop ran
    queue_delays: List[float] = dataclasses.field(default_factory=list)
    rejected: int = 0              # oversized requests turned away
    preemptions: int = 0           # paged slots evicted + recomputed
    dropped: int = 0               # stranded: never finished (no finish_time)
    # prefix-cache counters (PagedPipelineBatcher with prefix_caching=True)
    prefix_lookups: int = 0        # admissions that consulted the index
    prefix_hits: int = 0           # admissions that aliased >= 1 block
    prefix_hit_tokens: int = 0     # prompt tokens served from resident blocks
    prefill_tokens: int = 0        # cold prompt tokens actually prefilled
    cow_copies: int = 0            # shared blocks copied before a write
    # disaggregated prefill/decode (role="prefill" workers, sender side)
    migrations: int = 0            # finished prefills handed to a decoder
    migrated_kv_bytes: int = 0     # KV payload bytes shipped over the link
    # speculative decoding (PagedPipelineBatcher with spec=SpecConfig)
    spec_steps: int = 0            # target multi-token verification steps
    spec_proposed: int = 0         # draft tokens proposed
    spec_accepted: int = 0         # draft tokens the target agreed with
    spec_tokens: int = 0           # tokens committed via verification steps
    # quantized KV pages (PagedPipelineBatcher with kv_dtype="int8"/"fp8")
    kv_bytes_resident: int = 0     # allocated page-pool bytes (+ scales)
    kv_bytes_saved: int = 0        # bytes saved vs model-default pools
    # host page tier (PagedPipelineBatcher with host_blocks > 0)
    host_demotions: int = 0        # blocks spilled device -> host on evict
    host_promotions: int = 0       # blocks swapped back host -> device
    host_evictions: int = 0        # host-tier LRU drops (pages truly lost)
    host_hit_tokens: int = 0       # prompt tokens served from the host tier
    # cluster prefix directory (serving.cluster_kv)
    prefix_fetches: int = 0        # prefix blocks migrated from peer replicas
    prefix_fetched_bytes: int = 0  # payload bytes shipped for those fetches
    # KVSAN runtime sanitizer (PagedPipelineBatcher(kvsan=True))
    kvsan_leaks: int = 0           # pool references no table/index explains
    # total requests this replay accounted (served + rejected + dropped);
    # merge() weights attainment by it
    n_requests: int = 0

    def summary(self) -> str:
        lat = np.asarray(self.latencies)
        if len(lat):
            pct = (f"p50={np.percentile(lat, 50):.3f}s "
                   f"p99={np.percentile(lat, 99):.3f}s ")
        else:                      # zero served (e.g. all rejected/dropped)
            pct = "p50=n/a p99=n/a "
        extra = ""
        if self.prefix_lookups:
            hit = self.prefix_hits / self.prefix_lookups
            extra = (f" hit={hit * 100:.0f}% "
                     f"saved={self.prefix_hit_tokens}tok "
                     f"cow={self.cow_copies}")
        if self.migrations:
            extra += (f" mig={self.migrations} "
                      f"({self.migrated_kv_bytes / 1e6:.2f}MB)")
        if self.spec_steps:
            acc = (self.spec_accepted / self.spec_proposed
                   if self.spec_proposed else 0.0)
            extra += (f" spec={self.spec_tokens}tok"
                      f"/{self.spec_steps}step "
                      f"acc={acc * 100:.0f}%")
        if self.kv_bytes_saved:
            extra += (f" kv={self.kv_bytes_resident / 1e6:.2f}MB "
                      f"(-{self.kv_bytes_saved / 1e6:.2f}MB)")
        if self.host_demotions or self.host_promotions:
            extra += (f" host={self.host_promotions}in/"
                      f"{self.host_demotions}out "
                      f"({self.host_hit_tokens}tok)")
        if self.prefix_fetches:
            extra += (f" fetch={self.prefix_fetches} "
                      f"({self.prefix_fetched_bytes / 1e6:.2f}MB)")
        if self.kvsan_leaks:
            extra += f" KVSAN-LEAKS={self.kvsan_leaks}"
        return (f"n={len(lat)} {pct}"
                f"slo={self.attainment * 100:.1f}% thpt={self.throughput:.2f} req/s "
                f"rej={self.rejected} drop={self.dropped} "
                f"preempt={self.preemptions}{extra}")

    @classmethod
    def from_requests(cls, requests: Sequence, deadline: float,
                      *, iterations: int = 0) -> "ServeStats":
        # three outcomes: SERVED (finished with its tokens), REJECTED
        # (finished with an empty output despite wanting tokens), DROPPED
        # (stranded in the loop, finish_time still None). Latency
        # percentiles and throughput cover served requests only — a
        # rejected request's near-instant turnaround served nobody, and a
        # dropped request has no finish time at all; both count against
        # attainment.
        served = [r for r in requests if r.served]
        dropped = sum(1 for r in requests if r.finish_time is None)
        lats = [r.latency for r in served]

        def attained(r):
            return r.served and r.latency <= deadline
        att = (float(np.mean([attained(r) for r in requests]))
               if requests else 1.0)
        dur = max((r.finish_time for r in served), default=1.0)
        qd = [r.start_time - r.arrival for r in requests
              if r.start_time is not None]
        return cls(latencies=lats, attainment=att,
                   throughput=len(served) / max(dur, 1e-9),
                   iterations=iterations, queue_delays=qd, dropped=dropped,
                   n_requests=len(requests))

    # ---- aggregation across replicas / runs ------------------------------
    @classmethod
    def merge(cls, parts: Sequence["ServeStats"]) -> "ServeStats":
        """Aggregate stats across replicas or runs: integer counters sum,
        percentile inputs (latencies, queue delays) concatenate, SLO
        attainment weights by each part's request count, and throughput
        adds (parts are concurrent replicas of one serve window; for
        sequential runs, recompute from the merged requests instead).
        Degenerate inputs are safe: no parts -> the neutral stats, parts
        with zero requests contribute nothing to attainment."""
        parts = list(parts)
        if not parts:
            return cls(latencies=[], attainment=1.0, throughput=0.0)
        out = cls(latencies=[], attainment=1.0, throughput=0.0)
        for f in dataclasses.fields(cls):
            if f.name in ("latencies", "queue_delays", "attainment",
                          "throughput"):
                continue
            setattr(out, f.name, sum(getattr(p, f.name) for p in parts))
        for p in parts:
            out.latencies.extend(p.latencies)
            out.queue_delays.extend(p.queue_delays)
            out.throughput += p.throughput
        total = sum(p.n_requests for p in parts)
        out.attainment = (sum(p.attainment * p.n_requests for p in parts)
                          / total) if total else 1.0
        return out

    # ---- metrics-registry view (repro.obs.metrics) -----------------------
    def publish(self, registry, **labels) -> None:
        """Publish this stats object into a MetricsRegistry: every counter
        field as a ``serve_<name>`` counter, attainment/throughput as
        gauges, and the percentile inputs as histograms. ServeStats stays
        the back-compat summary surface; the registry is the typed
        stream."""
        for f in dataclasses.fields(self):
            if f.name in ("latencies", "queue_delays", "attainment",
                          "throughput"):
                continue
            registry.counter("serve_" + f.name, **labels).inc(
                getattr(self, f.name))
        registry.gauge("serve_attainment", **labels).set(self.attainment)
        registry.gauge("serve_throughput", **labels).set(self.throughput)
        lat = registry.histogram("request_latency_seconds", **labels)
        for v in self.latencies:
            lat.observe(float(v))
        qd = registry.histogram("queue_delay_seconds", **labels)
        for v in self.queue_delays:
            qd.observe(float(v))

    @classmethod
    def from_metrics(cls, registry, **labels) -> "ServeStats":
        """Rebuild a ServeStats view from a registry ``publish`` wrote to.
        Counters and gauges reconstruct exactly; latency/queue-delay
        SAMPLES are approximated by histogram bucket upper bounds (the
        registry keeps distributions, not raw values), so percentiles are
        bucket-resolution estimates."""
        out = cls(latencies=[], attainment=1.0, throughput=0.0)
        for f in dataclasses.fields(cls):
            if f.name in ("latencies", "queue_delays", "attainment",
                          "throughput"):
                continue
            v = registry.value("serve_" + f.name, **labels)
            if v is not None:
                setattr(out, f.name, int(v))
        att = registry.value("serve_attainment", **labels)
        thpt = registry.value("serve_throughput", **labels)
        out.attainment = att if att is not None else 1.0
        out.throughput = thpt if thpt is not None else 0.0

        def _samples(name):
            for ls, h in registry.histograms(name):
                if ls != {k: str(v) for k, v in labels.items()}:
                    continue
                for i, c in enumerate(h.counts):
                    ub = (h.buckets[i] if i < len(h.buckets)
                          else (h.max if h.max is not None else 0.0))
                    yield from [ub] * c
        out.latencies = list(_samples("request_latency_seconds"))
        out.queue_delays = list(_samples("queue_delay_seconds"))
        return out


# ---------------------------------------------------------------------------
# The loop
# ---------------------------------------------------------------------------

def run_serve_loop(workers: Sequence, requests: Sequence, *, deadline: float,
                   clock=None, dispatch=None, tracer=None,
                   metrics=None) -> ServeStats:
    """Replay a timed workload over `workers` and account the outcome.

    Mutates each request in place (`start_time`, `finish_time`, `output`)
    and returns the ServeStats. Dispatch is iteration-level least-loaded
    with a DETERMINISTIC tiebreak (lowest replica id, falling back to
    worker order) so identical workloads route identically run-to-run;
    ``dispatch(cands, req, now) -> worker`` overrides the choice entirely
    (the Router's prefix-aware scoring, seeded tiebreaks).

    ``tracer`` (repro.obs.trace.Tracer) records queue-wait and per-worker
    iteration spans against this loop's clock — pure observation, token
    streams are identical with it on or off. ``metrics``
    (repro.obs.metrics.MetricsRegistry) receives per-replica counter
    deltas, engine gauges (``metrics_gauges`` port) and the final
    ServeStats publication.
    """
    clock = clock if clock is not None else WallClock()
    tracer = tracer if tracer is not None else NULL_TRACER
    if tracer.enabled:
        tracer.bind_clock(clock)
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    idx = 0
    iterations = 0
    # workers persist across serve() calls: report this replay's deltas.
    counters = ("rejected", "preemptions", "prefix_lookups", "prefix_hits",
                "prefix_hit_tokens", "prefill_tokens", "cow_copies",
                "migrations", "migrated_kv_bytes", "spec_steps",
                "spec_proposed", "spec_accepted", "spec_tokens",
                "kv_bytes_resident", "kv_bytes_saved",
                "host_demotions", "host_promotions", "host_evictions",
                "host_hit_tokens", "prefix_fetches", "prefix_fetched_bytes",
                "kvsan_leaks")
    # MEMBERSHIP IS DYNAMIC: `workers` is consulted live each cycle, so a
    # controller (serving.resched.OnlineRescheduler) removing a dead
    # replica or adding a new one mid-serve is visible next iteration.
    # `seen` retains every worker that EVER served this replay with its
    # counter baseline, so a removed replica's pre-removal work still
    # lands in the final ServeStats instead of vanishing with it.
    wid: dict = {}
    seen: dict = {}

    def _register(ws) -> None:
        for w in ws:
            k = id(w)
            if k not in seen:
                wid[k] = getattr(w, "replica_id", len(wid))
                seen[k] = (w, {c: getattr(w, c, 0) for c in counters})

    _register(workers)
    # serve-level span: the sanctioned begin/end pair (repro-lint
    # span-pairing holds every begin to a matching end on its code path)
    serve_span = tracer.begin("serve") if tracer.enabled else None
    while idx < len(pending) or any(w.inflight() for w in workers):
        now = clock.now()
        _register(workers)         # pick up replicas added last cycle
        progressed = False

        # -- admission: due arrivals onto the least-loaded worker ---------
        while idx < len(pending) and pending[idx].arrival <= now:
            cands = [w for w in workers if w.capacity(now) > 0]
            if not cands:
                break
            req = pending[idx]
            if dispatch is not None:
                w = dispatch(cands, req, now)
            else:
                w = min(cands, key=lambda c: (c.load(now), wid[id(c)]))
            req.start_time = now
            w.admit([req], now)
            if tracer.enabled:
                # queue wait: arrival -> admission, on the chosen replica
                tracer.complete("queue_wait", now - req.arrival,
                                ts=req.arrival, pid=wid[id(w)],
                                rid=req.rid)
            idx += 1
            progressed = True

        # -- one iteration on every busy worker ---------------------------
        # Workers are parallel replicas: in virtual time a cycle costs the
        # SLOWEST busy worker's iteration, not the sum, so the clock ticks
        # once per cycle and completions are stamped after the tick.
        # (snapshot the list: a controller's run_iteration may add or
        # remove replicas, which take effect next cycle)
        max_cost = 0.0
        completed = []
        for w in list(workers):
            if not w.busy(now):
                continue
            done, cost = w.run_iteration(now)
            iterations += 1
            progressed = True
            max_cost = max(max_cost, cost)
            completed.extend(done)
            if tracer.enabled:
                # per-worker iteration span: the clock does not advance
                # DURING an iteration (one tick per cycle, below), so the
                # engine-reported cost is the span's duration
                tracer.complete("iteration", cost, ts=now,
                                pid=wid[id(w)], completions=len(done))
        if max_cost:
            clock.tick(max_cost)
        stamp = clock.now()
        for req, out, when in completed:
            if out is not None:
                req.output = out
            req.finish_time = when if when is not None else stamp

        if progressed:
            continue

        # -- idle: advance the clock to the next FUTURE event -------------
        # (a due-but-unadmittable arrival is not a target: with every
        # worker at zero capacity it cannot progress, and sleeping to a
        # past instant would spin the loop forever — it gets admitted
        # when a worker completion frees capacity)
        targets = []
        if idx < len(pending) and pending[idx].arrival > now:
            targets.append(pending[idx].arrival)
        for w in list(workers):
            t = w.next_event(now)
            if t is not None and t > now:
                targets.append(t)
        if not targets:
            # nothing runnable, nothing scheduled: any request still
            # pending or inflight is STRANDED — it keeps finish_time None
            # and ServeStats reports it as dropped / non-attained instead
            # of a negative latency
            break
        clock.sleep_until(min(targets))

    if serve_span is not None:
        tracer.end(serve_span, requests=len(pending))
    # satellite: with tracing on, the span stream is the source of truth
    # for first_token_time / prefill_finish_time — re-derive them (the
    # values must equal the engines' inline stamps; tests assert it)
    tracer.apply_marks(pending)
    stats = ServeStats.from_requests(pending, deadline,
                                     iterations=iterations)
    for c in counters:
        setattr(stats, c, sum(getattr(w, c, 0) - b[c]
                              for w, b in seen.values()))
    if metrics is not None:
        for w, b in seen.values():
            rep = str(wid[id(w)])
            for c in counters:
                d = getattr(w, c, 0) - b[c]
                if d:
                    metrics.counter("serve_" + c, replica=rep).inc(d)
            gauges = getattr(w, "metrics_gauges", None)
            if gauges is not None:
                for name, lbls, val in gauges():
                    metrics.gauge(name, replica=rep, **lbls).set(val)
        stats.publish(metrics)
    return stats
