"""Inference engine: builds replica pipelines from a scheduled Assignment and
serves workloads through the Router.

The Assignment's global device ids map onto actual jax devices: on a real
heterogeneous deployment those are the pool's accelerators; in this repo's
CPU demonstration they are host devices (tests spawn a subprocess with
``--xla_force_host_platform_device_count`` to get several).
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import Assignment
from repro.models import model as M
from repro.serving.disagg import KVLink
from repro.serving.pipeline import (AsymmetricPipeline,
                                    context_mode_supported,
                                    slot_mode_supported)
from repro.serving.request import Request
from repro.serving.router import Router, ServeStats, default_roles
from repro.serving.spec import SpecConfig


class InferenceEngine:
    """``disaggregate=True`` splits the inference phases across replicas:
    arrivals prefill on ``role="prefill"`` replicas and their KV pages
    migrate to ``role="decode"`` replicas (serving.disagg). ``roles``
    overrides the default split (e.g. the scheduler's SLO-scored one);
    the transfer is modeled as ``kv_bytes / link_bandwidth`` on the
    serving clock — flat via ``kv_link_gbps`` (0 = ideal interconnect),
    or per-replica-pair from ``cluster``'s comm matrices when given.

    ``spec_decode=True`` turns on speculative decoding (serving.spec):
    a proposer guesses up to ``spec_k`` tokens per slot per iteration and
    the target commits the verified prefix in one multi-token step —
    token-identical to plain greedy decode. ``draft_model`` names a small
    draft architecture from ``configs/`` (or passes a ModelConfig
    directly); without it the weight-free n-gram/prompt-lookup proposer
    runs. ``spec_ks`` overrides the depth PER REPLICA (the scheduler's
    acceptance-aware ``SearchResult.spec_ks``; 0 disables speculation on
    that replica). Needs the paged layout and an attention-only stack.

    ``kv_dtype`` stores the paged KV pools at reduced precision
    ("fp32"/"bf16"/"int8"/"fp8"; int8/fp8 pages carry per-token-per-head
    scales and dequantize inside the paged kernels). ``kv_dtypes``
    overrides PER REPLICA (the scheduler's ``SearchResult.kv_dtypes``;
    None entry = model default); ``kv_guard_layers`` pins those global
    layer indices at model precision (quality guard, typically the
    first/last layers). Needs the paged layout.

    ``kvsan`` serves under the KVSAN page-lifecycle sanitizer
    (repro.analysis.kvsan): pure observation, token-identical, leaks
    surface as ``ServeStats.kvsan_leaks``. Needs the paged layout.

    ``host_blocks`` (one int, or per replica — the scheduler's
    ``SearchResult.host_blocks``) adds a host-memory page tier under each
    replica's device pools: prefix eviction demotes pages there instead
    of deleting them, and matches swap them back in at ``host_swap_cost``
    per block on the serving clock. ``cluster_prefix=True`` joins every
    replica into a shared prefix directory (serving.cluster_kv): prompts
    whose prefix lives only on a peer fetch the pages over the KV link,
    and the Router scores admission by resident prefix
    (``prefix_route_weight`` / ``host_route_weight``) against queue
    depth instead of pure least-loaded; ``route_seed`` makes tiebreaks
    seeded-random for routing benchmarks. Both need prefix_caching."""

    def __init__(self, cfg: ModelConfig, assignment: Assignment, *,
                 params=None, key=None, devices: Optional[Sequence] = None,
                 max_batch: int = 4, quantize: bool = False,
                 policy: str = "continuous", n_slots: int = 8,
                 max_len: int = 256, cache_layout: str = "contiguous",
                 block_size: int = 16, stage_blocks=None,
                 prefix_caching: bool = False, prefill_chunk: int = 0,
                 host_blocks=0, host_swap_cost: float = 0.0,
                 cluster_prefix: bool = False,
                 prefix_route_weight: float = 0.25,
                 host_route_weight: float = 0.5,
                 route_seed: Optional[int] = None,
                 disaggregate: bool = False,
                 roles: Optional[Sequence[str]] = None,
                 kv_link_gbps: float = 0.0, cluster=None,
                 step_costs: Optional[Sequence[float]] = None,
                 prefill_token_cost: float = 0.0,
                 spec_decode: bool = False, spec_k: int = 4,
                 draft_model=None,
                 spec_ks: Optional[Sequence[int]] = None,
                 spec_draft_token_cost: float = 0.0,
                 kv_dtype: Optional[str] = None,
                 kv_dtypes: Optional[Sequence[Optional[str]]] = None,
                 kv_guard_layers: Sequence[int] = (),
                 kvsan: bool = False):
        self.cfg = cfg
        devices = list(devices if devices is not None else jax.devices())
        if params is None:
            params = M.init_params(
                cfg, key if key is not None else jax.random.PRNGKey(0))
        if quantize:
            from repro.models.quant import quantize_params
            params = quantize_params(params, cfg)
        self.replicas: List[AsymmetricPipeline] = []
        for pipe in assignment.pipelines:
            stage_devs = []
            for st in pipe.stages:
                mapped = [devices[d % len(devices)] for d in st.device_ids]
                # fewer physical devices than the plan's TP degree: collapse
                # duplicates (numerically identical; TP only changes layout)
                uniq = list(dict.fromkeys(mapped))
                stage_devs.append(uniq)
            self.replicas.append(AsymmetricPipeline(
                cfg, params, pipe.layer_split, stage_devs))
        if policy != "static" and not slot_mode_supported(cfg):
            warnings.warn(
                f"{cfg.name}: slot mode needs uniform text decode "
                "(SWA ring cache / encoder-decoder / VLM); serving with "
                "policy='static'", stacklevel=2)
            policy = "static"
        # ---- disaggregated prefill/decode ------------------------------
        if disaggregate and roles is None:
            roles = default_roles(len(self.replicas))
        if roles is not None and any(r != "both" for r in roles):
            if not context_mode_supported(cfg):
                warnings.warn(
                    f"{cfg.name}: disaggregation needs an attention-only "
                    "stack (recurrent running state has no pages to "
                    "migrate); serving colocated", stacklevel=2)
                roles = None
            elif len(self.replicas) < 2:
                warnings.warn(
                    "disaggregation needs >= 2 replicas; serving "
                    "colocated", stacklevel=2)
                roles = None
        # ---- speculative decoding --------------------------------------
        spec = None
        if spec_decode and spec_k < 1:
            # consistent with per-replica spec_ks, where 0 = plain decode
            warnings.warn("spec_k < 1 means plain decode; serving without "
                          "speculation", stacklevel=2)
            spec_decode = False
            spec_ks = None
        if spec_decode:
            if not context_mode_supported(cfg):
                warnings.warn(
                    f"{cfg.name}: speculative decoding needs an "
                    "attention-only stack (a recurrent sublayer's state "
                    "cannot roll back past a rejected candidate); serving "
                    "without it", stacklevel=2)
                spec_ks = None
            elif draft_model is not None:
                draft_cfg = draft_model
                if isinstance(draft_model, str):
                    from repro.configs import get_config
                    draft_cfg = get_config(draft_model)
                    if cfg.name.endswith("-reduced"):
                        draft_cfg = draft_cfg.reduced()
                if not context_mode_supported(draft_cfg):
                    warnings.warn(
                        f"{draft_cfg.name}: draft models must be "
                        "attention-only text decoders (recurrent draft "
                        "state cannot roll back past a rejected "
                        "candidate); falling back to the n-gram proposer",
                        stacklevel=2)
                    draft_cfg = None
                elif draft_cfg.vocab_size != cfg.vocab_size:
                    warnings.warn(
                        f"{draft_cfg.name}: draft vocab "
                        f"({draft_cfg.vocab_size}) differs from the "
                        f"target's ({cfg.vocab_size}); falling back to "
                        "the n-gram proposer", stacklevel=2)
                    draft_cfg = None
                if draft_cfg is not None:
                    spec = SpecConfig(
                        k=spec_k, proposer="draft", draft_cfg=draft_cfg,
                        draft_token_cost=spec_draft_token_cost)
                else:
                    spec = SpecConfig(
                        k=spec_k, draft_token_cost=spec_draft_token_cost)
            else:
                spec = SpecConfig(k=spec_k,
                                  draft_token_cost=spec_draft_token_cost)
        kv_link = None
        if (roles is not None and any(r != "both" for r in roles)) \
                or cluster_prefix:
            if cluster is not None:
                # per-pair alpha-beta costs: source replica's LAST stage to
                # destination replica's FIRST stage, like the cost model's
                # pipeline-comm term
                src = [list(p.stages[-1].device_ids)
                       for p in assignment.pipelines]
                dst = [list(p.stages[0].device_ids)
                       for p in assignment.pipelines]
                kv_link = KVLink.from_cluster(
                    cluster, [p.device_ids for p in assignment.pipelines],
                    src_stage_devices=src, dst_stage_devices=dst)
            else:
                kv_link = KVLink(gbps=kv_link_gbps)
        self.router = Router(self.replicas, max_batch=max_batch,
                             policy=policy, n_slots=n_slots, max_len=max_len,
                             cache_layout=cache_layout,
                             block_size=block_size,
                             stage_blocks=stage_blocks,
                             prefix_caching=prefix_caching,
                             prefill_chunk=prefill_chunk,
                             host_blocks=host_blocks,
                             host_swap_cost=host_swap_cost,
                             cluster_prefix=cluster_prefix,
                             prefix_route_weight=prefix_route_weight,
                             host_route_weight=host_route_weight,
                             route_seed=route_seed,
                             roles=roles, kv_link=kv_link,
                             step_costs=step_costs,
                             prefill_token_cost=prefill_token_cost,
                             spec=spec,
                             spec_ks=(list(spec_ks)
                                      if spec_ks is not None else None),
                             kv_dtype=kv_dtype,
                             kv_dtypes=(list(kv_dtypes)
                                        if kv_dtypes is not None else None),
                             kv_guard_layers=kv_guard_layers,
                             kvsan=kvsan)
        self.roles = self.router.roles

    @classmethod
    def from_config(cls, cfg: ModelConfig, plan, serving, *,
                    assignment: Optional[Assignment] = None, key=None,
                    cluster=None, **overrides) -> "InferenceEngine":
        """Build an engine from the two typed surfaces: a
        ``serving.config.ServingConfig`` (HOW to serve — policy, layout,
        feature flags) and a ``core.plan.DeploymentPlan`` (WHERE — the
        scheduler's replica layouts, roles, spec depths, KV precisions and
        host-tier split). ``assignment`` overrides the plan's layer split
        (e.g. the reduced-model projection from launch.serve) while the
        plan keeps supplying the per-replica dimensions; ``cluster`` feeds
        the per-pair KV-link cost model when no flat bandwidth is set;
        ``overrides`` pass through any raw ``__init__`` kwarg (n_slots,
        params, devices, ...)."""
        sv = serving.normalized()
        asg = assignment if assignment is not None else plan.assignment
        kw = dict(
            key=(key if key is not None
                 else jax.random.PRNGKey(sv.seed)),
            policy=sv.policy, max_len=sv.max_len(),
            cache_layout=sv.cache_layout, block_size=sv.block_size,
            prefix_caching=sv.prefix_caching,
            prefill_chunk=sv.prefill_chunk,
            host_blocks=(plan.host_blocks
                         if plan.host_blocks is not None else 0),
            host_swap_cost=sv.host_swap_cost,
            cluster_prefix=sv.cluster_prefix,
            prefix_route_weight=sv.prefix_route_weight,
            route_seed=sv.route_seed,
            # the role split is the SCHEDULER's verdict: roles=None means
            # colocated serving won the search, so don't force a default
            disaggregate=(sv.disaggregate and plan.roles is not None),
            roles=(plan.roles if sv.disaggregate else None),
            kv_link_gbps=sv.kv_link_gbps,
            cluster=(cluster if sv.disaggregate and sv.kv_link_gbps <= 0
                     else None),
            spec_decode=sv.spec_decode, spec_k=sv.spec_k,
            draft_model=(sv.draft_model or None),
            spec_draft_token_cost=sv.spec_draft_cost,
            spec_ks=(plan.spec_ks if sv.spec_decode else None),
            kv_dtype=sv.fixed_kv_dtype(),
            kv_dtypes=(plan.kv_dtypes if sv.kv_dtype == "search"
                       else None),
            kv_guard_layers=sv.guard_layers(cfg.num_layers),
            kvsan=sv.kvsan)
        kw.update(overrides)
        return cls(cfg, asg, **kw)

    def generate(self, prompts: Sequence[np.ndarray], *, max_new: int = 16
                 ) -> List[np.ndarray]:
        """One-shot batched generation on replica 0."""
        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((len(prompts), maxlen), np.int32)
        kv_start = np.zeros(len(prompts), np.int32)
        for i, p in enumerate(prompts):
            toks[i, maxlen - len(p):] = p
            kv_start[i] = maxlen - len(p)
        out = self.replicas[0].generate(toks, max_new=max_new,
                                        kv_start=kv_start)
        return [out[i] for i in range(len(prompts))]

    def serve(self, requests: Sequence[Request], *, deadline: float,
              clock=None, tracer=None, metrics=None) -> ServeStats:
        return self.router.serve(requests, deadline, clock=clock,
                                 tracer=tracer, metrics=metrics)
