"""Online rescheduling executor: the serve loop's closed feedback loop.

``OnlineRescheduler`` is a CONTROLLER THAT RIDES THE SERVE LOOP as one
more worker (the replica port of ``serving.loop``): it never admits
requests (capacity 0), but each cycle it may

  1. execute scheduled replica kills (chaos injection) or react to
     deaths reported by the caller,
  2. poll the drift detector (core.resched.DriftDetector) and, when a
     signal fires, invoke the re-solve callback and apply the new layout
     through the live migration executor, and
  3. re-dispatch orphaned requests onto surviving replicas.

Membership is DYNAMIC: the controller mutates the same ``workers`` list
the loop re-reads every cycle (serving.loop grew per-cycle registration
for exactly this), so a removed replica stops receiving work next
iteration and an added one becomes a dispatch candidate immediately.

Token safety is the invariant the whole design hangs on:

  * a PLANNED move extracts a decoding slot's pages + sampling state +
    emitted tokens (``PagedPipelineBatcher.extract_live_slots``) and
    re-seeds them at the destination (``_place_migrations``) — the
    stream continues exactly where it stopped, never re-emitting or
    skipping a token;
  * a KILL loses the replica's pages, so its requests re-dispatch from
    their prompts — greedy decode regenerates the identical stream, so
    failure costs latency, never correctness ("never a wrong token, at
    worst a cold re-prefill").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.resched import DriftDetector, DriftSignal
from repro.obs.trace import NULL_TRACER
from repro.serving.disagg import KVDispatcher, KVLink
from repro.serving.request import Request

__all__ = ["OnlineRescheduler", "evacuate_worker"]


def evacuate_worker(w, now: float) -> List[Request]:
    """Pull every in-flight request out of `w` and release its state.

    Real paged engines implement ``evacuate`` themselves (KVSAN-clean
    page release); analytic workers (core.slo_sim) and the static batcher
    are drained generically through their queues/heaps so the chaos
    benchmark can kill simulated replicas through the same controller."""
    if hasattr(w, "evacuate"):
        return list(w.evacuate(now))
    orphans: List[Request] = []
    for attr in ("_queue", "_pending", "_events", "_migrations"):
        store = getattr(w, attr, None)
        if store is None:
            continue
        for item in list(store):
            if isinstance(item, tuple):      # heap entries (..., request)
                orphans.append(item[-1])
            else:
                orphans.append(item)
        try:
            store.clear()
        except AttributeError:               # plain list heaps
            del store[:]
    return [r for r in orphans if isinstance(r, Request)]


class OnlineRescheduler:
    """Drift-aware controller + live migration executor, as a loop worker.

    Parameters
    ----------
    detector: optional ``core.resched.DriftDetector``; polled every cycle
        once bound. Replica kills are reported to it automatically.
    resolver: optional callback ``resolver(signal, controller, now)``
        invoked when the detector fires. It may return None (signal
        noted, nothing applied) or a dict of actions understood by
        ``apply_actions``:
          {"roles": [...]}            live role re-split of the survivors
          {"workers": [...], "roles": [...]}
                                      whole-set replacement (re-solved
                                      layout); old workers evacuate, their
                                      requests re-dispatch onto the new set
    kills: scheduled chaos events, (time, replica_id) pairs — executed on
        the serving clock. Replica ids are the workers' ``replica_id``s
        at bind time.
    link: KVLink pricing live slot moves (None = instantaneous).
    """

    def __init__(self, *, detector: Optional[DriftDetector] = None,
                 resolver: Optional[Callable] = None,
                 kills: Sequence[Tuple[float, int]] = (),
                 link: Optional[KVLink] = None):
        self.detector = detector
        self.resolver = resolver
        self._kills = sorted(kills)
        self.link = link if link is not None else KVLink()
        self.router = None
        self.workers: Optional[List] = None
        self._by_id: dict = {}
        self._orphans: List[Request] = []
        self._signal: Optional[DriftSignal] = None
        self._spec_seen = (0, 0)
        self.events: List[dict] = []
        self.redispatches = 0
        self.tracer = NULL_TRACER  # Router.bind_tracer swaps in the live one

    # ---- binding ---------------------------------------------------------
    def bind(self, router) -> None:
        """Bind to a Router (its live ``workers`` list and dispatcher)."""
        self.router = router
        self.bind_workers(router.workers)

    def bind_workers(self, workers: List) -> None:
        """Bind to a bare worker list (analytic chaos benchmarks); the
        list object is shared with ``run_serve_loop`` so membership edits
        are visible to the loop."""
        self.workers = workers
        self._by_id = {getattr(w, "replica_id", i): w
                       for i, w in enumerate(workers)}

    def _peers(self) -> List:
        return [w for w in (self.workers or []) if w is not self]

    # ---- observation hooks (Router._dispatch) ----------------------------
    def observe_admit(self, now: float, req: Request) -> None:
        if self.detector is not None:
            self.detector.observe_admit(now, len(req.prompt))

    def _harvest_spec(self) -> None:
        if self.detector is None:
            return
        prop = sum(getattr(w, "spec_proposed", 0) for w in self._peers())
        acc = sum(getattr(w, "spec_accepted", 0) for w in self._peers())
        p0, a0 = self._spec_seen
        if prop > p0 or acc > a0:
            self.detector.observe_spec(prop - p0, acc - a0)
            self._spec_seen = (prop, acc)

    # ---- the replica port (serving.loop) ---------------------------------
    def capacity(self, now: float) -> int:
        return 0                   # never a dispatch candidate

    def load(self, now: float) -> float:
        return float("inf")

    def admit(self, reqs, now: float) -> None:
        raise AssertionError("the controller admits nothing")

    def inflight(self) -> int:
        # orphans keep the loop alive until they land somewhere
        return len(self._orphans)

    def next_event(self, now: float):
        for t, _ in self._kills:
            if t > now:
                return t
        return None

    def busy(self, now: float) -> bool:
        if self._kills and self._kills[0][0] <= now:
            return True
        if self._orphans and self._placeable(now):
            return True
        if self._signal is None and self.detector is not None:
            self._harvest_spec()
            self._signal = self.detector.poll(now)
        return self._signal is not None

    def _placeable(self, now: float) -> bool:
        return any(w.capacity(now) > 0 for w in self._peers())

    def run_iteration(self, now: float):
        while self._kills and self._kills[0][0] <= now:
            _, rid = self._kills.pop(0)
            self.kill(rid, now)
        if self._signal is None and self.detector is not None:
            self._harvest_spec()
            self._signal = self.detector.poll(now)
        if self._signal is not None:
            sig, self._signal = self._signal, None
            self.events.append({"t": now, "kind": "signal",
                                "what": sig.describe()})
            if self.resolver is not None:
                actions = self.resolver(sig, self, now)
                if actions:
                    self.apply_actions(actions, now)
        self._redispatch(now)
        return [], 0.0

    # ---- failure path ----------------------------------------------------
    def kill(self, replica_id, now: float) -> None:
        """Replica death: its pages are gone. Evacuate its in-flight
        requests (cold re-prefill elsewhere), remove it from the live
        membership, and repair the dispatcher wiring so the surviving
        role graph stays serveable."""
        w = self._by_id.get(replica_id)
        if w is None or w not in self._peers():
            return                 # already dead / unknown
        self._orphans.extend(evacuate_worker(w, now))
        self.workers.remove(w)
        self.events.append({"t": now, "kind": "kill",
                            "replica": replica_id,
                            "orphans": len(self._orphans)})
        if self.tracer.enabled:
            self.tracer.instant("replica_kill", ts=now, pid=replica_id,
                                orphans=len(self._orphans))
        if self.detector is not None:
            key = frozenset(getattr(w, "device_ids", ())) \
                or frozenset({replica_id})
            self.detector.observe_death(key)
        self._repair_wiring(now)

    def _repair_wiring(self, now: float) -> None:
        """Post-removal dispatcher repair: prune dead decode targets; if
        either side of a disaggregated split died out entirely, flip the
        survivors to colocated "both" — always serveable, never an
        island of prefill-only or decode-only replicas."""
        peers = self._peers()
        roles = [getattr(w, "role", "both") for w in peers]
        prefills = [w for w, r in zip(peers, roles) if r == "prefill"]
        decodes = [w for w, r in zip(peers, roles) if r == "decode"]
        disp = getattr(self.router, "dispatcher", None) \
            if self.router is not None else None
        if disp is None:
            for w in peers:
                d = getattr(w, "dispatcher", None)
                if d is not None:
                    disp = d
                    break
        if disp is not None:
            disp.targets = [t for t in disp.targets if t in peers]
        for w in peers:
            # analytic prefill workers (core.slo_sim) wire their decode
            # targets directly; prune the dead ones there too
            tg = getattr(w, "targets", None)
            if isinstance(tg, list):
                w.targets = [t for t in tg if t in peers]
        if (prefills and not decodes) or (decodes and not prefills) or \
                (disp is not None and not disp.targets and prefills):
            for w in peers:
                if getattr(w, "role", "both") != "both":
                    w.role = "both"
            if self.router is not None:
                self.router.roles = ["both"] * len(peers)
            self.events.append({"t": now, "kind": "colocate_fallback"})
        elif self.router is not None:
            self.router.roles = [getattr(w, "role", "both") for w in peers]

    # ---- planned migration (the live executor) ---------------------------
    def apply_actions(self, actions: dict, now: float) -> None:
        if "workers" in actions:
            self.replace_workers(actions["workers"], now,
                                 roles=actions.get("roles"))
        elif "roles" in actions:
            self.apply_roles(actions["roles"], now)

    def apply_roles(self, new_roles: Sequence[str], now: float) -> None:
        """Live role re-split of the surviving replicas WITHOUT draining:
        replicas losing decode capability hand their decoding slots to
        the new decode side as live migrations (pages + sampling state +
        emitted tokens); replicas turning pure-decode requeue their
        waiting arrivals for re-dispatch to a prefill-capable peer."""
        peers = self._peers()
        assert len(new_roles) == len(peers), (new_roles, len(peers))
        old_roles = [getattr(w, "role", "both") for w in peers]
        decodes = [w for w, r in zip(peers, new_roles) if r == "decode"]
        prefills = [w for w, r in zip(peers, new_roles) if r == "prefill"]
        assert bool(prefills) == bool(decodes), (new_roles,)
        disp = KVDispatcher(decodes, self.link) if decodes else None
        if disp is not None:
            disp.tracer = self.tracer
        for w, old, new in zip(peers, old_roles, new_roles):
            w.role = new
            if new == "prefill":
                w.dispatcher = disp
        moved = 0
        for w, old, new in zip(peers, old_roles, new_roles):
            if new == "prefill" and old in ("both", "decode") \
                    and disp is not None \
                    and hasattr(w, "extract_live_slots"):
                for mig in w.extract_live_slots(now):
                    disp.send(w, mig, now)
                    moved += 1
            if new == "decode" and hasattr(w, "_queue") and w._queue:
                # waiting arrivals need a prefill-capable home
                self._orphans.extend(w._queue)
                w._queue.clear()
        if self.router is not None:
            self.router.roles = list(new_roles)
            self.router.dispatcher = disp
        self.events.append({"t": now, "kind": "roles",
                            "roles": list(new_roles), "moved": moved})

    def replace_workers(self, new_workers: Sequence, now: float, *,
                        roles: Optional[Sequence[str]] = None) -> None:
        """Swap the whole replica set for a re-solved layout: evacuate
        every current worker (their requests re-dispatch onto the new
        set) and install the new workers in the live membership list.
        Used by re-solves that change the device partitioning itself —
        per-slot live moves only work between layouts sharing a page
        size, so a repartition restarts in-flight work from prompts
        (still token-identical under greedy decode)."""
        assert self.workers is not None, "bind first"
        for w in self._peers():
            self._orphans.extend(evacuate_worker(w, now))
            self.workers.remove(w)
        insert = list(new_workers)
        if roles is not None:
            assert len(roles) == len(insert), (roles, len(insert))
            for w, r in zip(insert, roles):
                w.role = r
        if self.tracer.enabled:
            for w in insert:
                if hasattr(w, "tracer"):
                    w.tracer = self.tracer
        # keep the controller LAST so new workers admit before we run
        pos = self.workers.index(self) if self in self.workers \
            else len(self.workers)
        self.workers[pos:pos] = insert
        self._by_id.update({getattr(w, "replica_id", i): w
                            for i, w in enumerate(insert)})
        if self.router is not None:
            self.router.roles = [getattr(w, "role", "both")
                                 for w in insert]
        self.events.append({"t": now, "kind": "replace",
                            "n": len(insert)})

    # ---- orphan re-dispatch ----------------------------------------------
    def _redispatch(self, now: float) -> None:
        """Admit orphans onto surviving replicas, least-loaded first —
        the loop's own admission policy, re-applied after the membership
        change. Unplaceable orphans stay with the controller (inflight()
        keeps the loop alive) until a completion frees capacity."""
        kept: List[Request] = []
        for r in sorted(self._orphans,
                        key=lambda r: (r.arrival, r.rid)):
            cands = [w for w in self._peers() if w.capacity(now) > 0]
            if not cands:
                kept.append(r)
                continue
            w = min(cands, key=lambda c: (c.load(now),
                                          getattr(c, "replica_id", 0)))
            w.admit([r], now)
            self.redispatches += 1
        self._orphans = kept
