"""Cluster prefix directory: replica-spanning KV residency (beyond-paper,
cf. HexGen-2's disaggregated KV transfer + Helix's routing argument).

Each replica's ``PrefixIndex`` (PR 3) is private: the hottest KV in a real
deployment — system prompts, few-shot headers, RAG boilerplate — is
recomputed and evicted independently on every replica. This module turns
those private caches into one cluster-wide memory hierarchy:

  * ``ClusterPrefixDirectory`` — a shared map from chained chunk hashes
    (block_manager.chunk_hashes) to per-replica residency TIER ("device"
    page pool or "host" spill pool). Engines keep it coherent on
    register / demote / promote / evict; the Router reads it to score
    replicas by resident prefix length (prefix-aware routing), and an
    engine that misses locally reads it to find a peer to fetch from.
  * ``wire_cluster_prefix`` — attaches one directory + the peer table +
    a ``KVLink`` transfer model to every ``PagedPipelineBatcher``, the
    same wiring shape as ``disagg.wire_disaggregation``.

The directory is a HINT, not ground truth: a stale entry (the peer
evicted the page after publishing) makes the fetch fail gracefully — the
exporter returns None, the reader unpublishes the entry and prefills the
remainder cold. Token streams therefore never depend on directory
coherence, only the amount of recompute does.

Hot-prefix migration itself lives engine-side
(``continuous.PagedPipelineBatcher._materialize_hash`` /
``export_prefix_block``) and reuses the PR-4 wire format: per-GLOBAL-layer
``{"k","v"[,scales]}`` page payloads (``KVMigration``-shaped, so source
and destination may split stages differently) charged at ``KVLink.delay``
on the serving clock.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.disagg import KVLink

TIERS = ("device", "host")


class ClusterPrefixDirectory:
    """Chained chunk hash -> {replica_id: tier} residency map.

    ``publish`` upserts one replica's tier for a hash (a promotion or
    demotion just re-publishes at the new tier); ``unpublish`` drops the
    replica's claim entirely (the page left both tiers). Reads never
    mutate.
    """

    def __init__(self):
        self._res: Dict[int, Dict[int, str]] = {}

    def __len__(self) -> int:
        return len(self._res)

    def publish(self, h: int, replica: int, tier: str) -> None:
        assert tier in TIERS, tier
        self._res.setdefault(h, {})[replica] = tier

    def unpublish(self, h: int, replica: int) -> None:
        m = self._res.get(h)
        if m is None:
            return
        m.pop(replica, None)
        if not m:
            del self._res[h]

    def tier(self, h: int, replica: int) -> Optional[str]:
        return self._res.get(h, {}).get(replica)

    def holders(self, h: int, exclude: Optional[int] = None
                ) -> List[Tuple[int, str]]:
        """Replicas holding `h`, device tier first (an export from device
        pages skips the peer's swap-in), then by lowest replica id —
        deterministic fetch sourcing."""
        out = [(rid, t) for rid, t in self._res.get(h, {}).items()
               if rid != exclude]
        out.sort(key=lambda rt: (TIERS.index(rt[1]), rt[0]))
        return out

    def entries_for(self, replica: int) -> List[Tuple[int, str]]:
        """Every (hash, tier) this replica has published — KVSAN audits
        these against the replica's actual index / host-tier residency."""
        return [(h, m[replica]) for h, m in self._res.items()
                if replica in m]

    def resident_blocks(self, hashes: Sequence[int], replica: int
                        ) -> Tuple[int, int]:
        """(device_blocks, host_blocks) of the longest prefix of `hashes`
        resident on `replica` in ANY tier. Chained hashes only match
        head-first, so the walk stops at the first gap — exactly what a
        prefix-aware router should credit the replica for."""
        ndev = nhost = 0
        for h in hashes:
            t = self._res.get(h, {}).get(replica)
            if t == "device":
                ndev += 1
            elif t == "host":
                nhost += 1
            else:
                break
        return ndev, nhost


def wire_cluster_prefix(workers: Sequence, link: Optional[KVLink] = None,
                        directory: Optional[ClusterPrefixDirectory] = None
                        ) -> ClusterPrefixDirectory:
    """Join every worker into one shared prefix directory. Workers must be
    ``PagedPipelineBatcher``-shaped (``replica_id`` + ``attach_cluster``);
    ``link`` models the inter-replica transfer (None = ideal
    interconnect, the right default for bit-identity smokes)."""
    directory = directory if directory is not None \
        else ClusterPrefixDirectory()
    link = link if link is not None else KVLink()
    peers = {w.replica_id: w for w in workers}
    assert len(peers) == len(workers), "replica ids must be unique"
    for w in workers:
        w.attach_cluster(directory, peers, link)
    return directory
