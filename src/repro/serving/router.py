"""Task coordinator (paper Appendix C), upgraded beyond the paper: requests
are dispatched to replicas at ITERATION granularity through the shared
serving loop. Each replica runs slot-based continuous batching (the paper's
Appendix-D limitation), so a request admits as soon as any replica frees a
slot instead of waiting for a whole static batch to drain.

``policy="static"`` keeps the paper's own engine (left-padded whole-batch
``generate`` per dispatch) as a worker on the SAME loop, for before/after
measurement — there is exactly one serve-loop implementation either way.
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.loop import ServeStats, WallClock, run_serve_loop
from repro.serving.request import Request

__all__ = ["Router", "ServeStats", "StaticBatcher"]


class StaticBatcher:
    """The paper's engine as a loop worker: admitted requests accumulate up
    to max_batch and one iteration runs the whole left-padded batch to
    completion via ``replica.generate``."""

    def __init__(self, replica, *, max_batch: int = 4, pad_id: int = 0,
                 virtual_step_cost: float = 1.0):
        self.replica = replica
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.virtual_step_cost = virtual_step_cost
        self._queue: List[Request] = []

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        return self.max_batch - len(self._queue)

    def load(self, now: float) -> float:
        return len(self._queue)

    def admit(self, reqs: Sequence[Request], now: float) -> None:
        self._queue.extend(reqs)

    def busy(self, now: float) -> bool:
        return bool(self._queue)

    def inflight(self) -> int:
        return len(self._queue)

    def next_event(self, now: float):
        return None

    def run_iteration(self, now: float):
        batch, self._queue = self._queue, []
        maxlen = max(len(r.prompt) for r in batch)
        toks = np.full((len(batch), maxlen), self.pad_id, np.int32)
        kv_start = np.zeros(len(batch), np.int32)
        for i, r in enumerate(batch):
            toks[i, maxlen - len(r.prompt):] = r.prompt        # left pad
            kv_start[i] = maxlen - len(r.prompt)
        max_new = max(r.max_new_tokens for r in batch)
        out = self.replica.generate(toks, max_new=max_new, kv_start=kv_start)
        comps = [(r, out[i, :r.max_new_tokens], None)
                 for i, r in enumerate(batch)]
        return comps, self.virtual_step_cost * max_new


class Router:
    """Least-loaded dispatch over replicas, sharing the serve loop (and its
    admission policy) with the SLO simulator."""

    def __init__(self, replicas, *, max_batch: int = 4, pad_id: int = 0,
                 policy: str = "continuous", n_slots: int = 8,
                 max_len: int = 256, cache_layout: str = "contiguous",
                 block_size: int = 16, stage_blocks=None):
        assert policy in ("continuous", "static"), policy
        assert cache_layout in ("contiguous", "paged"), cache_layout
        self.replicas = list(replicas)
        self.policy = policy
        self.cache_layout = cache_layout
        if policy == "continuous" and cache_layout == "paged":
            self.workers = [PagedPipelineBatcher(
                r, n_slots=n_slots, max_len=max_len, pad_id=pad_id,
                block_size=block_size, stage_blocks=stage_blocks)
                for r in self.replicas]
        elif policy == "continuous":
            self.workers = [PipelineBatcher(r, n_slots=n_slots,
                                            max_len=max_len, pad_id=pad_id)
                            for r in self.replicas]
        else:
            if cache_layout == "paged":
                warnings.warn(
                    "cache_layout='paged' has no effect with "
                    "policy='static' (the whole-batch engine allocates "
                    "per-generate caches); serving contiguous",
                    stacklevel=2)
            self.workers = [StaticBatcher(r, max_batch=max_batch,
                                          pad_id=pad_id)
                            for r in self.replicas]

    def serve(self, requests: Sequence[Request], deadline: float, *,
              clock=None) -> ServeStats:
        """Replays a timed workload; wall-clock by default, or any Clock
        (e.g. VirtualClock for deterministic replay)."""
        return run_serve_loop(self.workers, requests, deadline=deadline,
                              clock=clock if clock is not None else WallClock())
