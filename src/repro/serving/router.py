"""Task coordinator (paper Appendix C), upgraded beyond the paper: requests
are dispatched to replicas at ITERATION granularity through the shared
serving loop. Each replica runs slot-based continuous batching (the paper's
Appendix-D limitation), so a request admits as soon as any replica frees a
slot instead of waiting for a whole static batch to drain.

``policy="static"`` keeps the paper's own engine (left-padded whole-batch
``generate`` per dispatch) as a worker on the SAME loop, for before/after
measurement — there is exactly one serve-loop implementation either way.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.block_manager import chunk_hashes
from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.disagg import KVLink, wire_disaggregation
from repro.serving.loop import ServeStats, WallClock, run_serve_loop
from repro.serving.request import Request
from repro.serving.spec import SpecConfig

__all__ = ["Router", "ServeStats", "StaticBatcher", "default_roles"]


def default_roles(n_replicas: int) -> List[str]:
    """Default disaggregated role split: decode replicas hold KV for a
    request's whole lifetime while prefill replicas turn requests over per
    prompt, so lean decode-heavy — floor(n/3) prefill replicas, at least
    one of each. The scheduler's role search (core.genetic) replaces this
    with an SLO-scored split."""
    n_prefill = max(1, n_replicas // 3)
    return ["prefill"] * n_prefill + ["decode"] * (n_replicas - n_prefill)


class StaticBatcher:
    """The paper's engine as a loop worker: admitted requests accumulate up
    to max_batch and one iteration runs the whole left-padded batch to
    completion via ``replica.generate``.

    ``max_len`` guards the replica's cache length: a request whose prompt +
    max_new_tokens cannot fit is rejected alone with an empty output
    (counted in ``ServeStats.rejected``) instead of crashing the whole
    replay mid-generate — the same graceful degradation SlotEngine._fits
    gives the continuous engines. None = unbounded (per-generate caches).
    """

    def __init__(self, replica, *, max_batch: int = 4, pad_id: int = 0,
                 max_len: Optional[int] = None,
                 virtual_step_cost: float = 1.0):
        self.replica = replica
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.max_len = max_len
        self.virtual_step_cost = virtual_step_cost
        self._queue: List[Request] = []
        self.rejected = 0

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        return self.max_batch - len(self._queue)

    def load(self, now: float) -> float:
        return len(self._queue)

    def admit(self, reqs: Sequence[Request], now: float) -> None:
        self._queue.extend(reqs)

    def busy(self, now: float) -> bool:
        return bool(self._queue)

    def inflight(self) -> int:
        return len(self._queue)

    def next_event(self, now: float):
        return None

    def run_iteration(self, now: float):
        batch, self._queue = self._queue, []
        comps = []
        if self.max_len is not None:
            fits = []
            for r in batch:
                if len(r.prompt) + r.max_new_tokens > self.max_len - 1:
                    self.rejected += 1
                    warnings.warn(
                        f"request {r.rid}: prompt {len(r.prompt)} + "
                        f"max_new {r.max_new_tokens} exceeds the replica "
                        "cache length; rejected with empty output")
                    comps.append((r, np.zeros(0, np.int32), None))
                else:
                    fits.append(r)
            batch = fits
        if not batch:
            return comps, self.virtual_step_cost
        maxlen = max(len(r.prompt) for r in batch)
        toks = np.full((len(batch), maxlen), self.pad_id, np.int32)
        kv_start = np.zeros(len(batch), np.int32)
        for i, r in enumerate(batch):
            toks[i, maxlen - len(r.prompt):] = r.prompt        # left pad
            kv_start[i] = maxlen - len(r.prompt)
        max_new = max(r.max_new_tokens for r in batch)
        out = self.replica.generate(toks, max_new=max_new, kv_start=kv_start)
        comps.extend((r, out[i, :r.max_new_tokens], None)
                     for i, r in enumerate(batch))
        return comps, self.virtual_step_cost * max_new


class Router:
    """Least-loaded dispatch over replicas, sharing the serve loop (and its
    admission policy) with the SLO simulator.

    ``max_len`` is the serving contract for EVERY policy: slot engines size
    their caches by it, and the static engine enforces it as its oversized
    guard — a request too big for the continuous engines is rejected by the
    static engine too (empty output, counted in ``ServeStats.rejected``)
    rather than silently served via an unbounded per-generate cache, so
    static-vs-continuous A/B runs see the same admission ceiling.
    Construct ``StaticBatcher`` directly with ``max_len=None`` for an
    unbounded whole-batch engine."""

    def __init__(self, replicas, *, max_batch: int = 4, pad_id: int = 0,
                 policy: str = "continuous", n_slots: int = 8,
                 max_len: int = 256, cache_layout: str = "contiguous",
                 block_size: int = 16, stage_blocks=None,
                 prefix_caching: bool = False, prefill_chunk: int = 0,
                 host_blocks=0, host_swap_cost: float = 0.0,
                 cluster_prefix: bool = False,
                 prefix_route_weight: float = 0.25,
                 host_route_weight: float = 0.5,
                 route_seed: Optional[int] = None,
                 roles: Optional[Sequence[str]] = None,
                 kv_link: Optional[KVLink] = None,
                 prefill_token_cost: float = 0.0,
                 step_costs: Optional[Sequence[float]] = None,
                 spec: Optional[SpecConfig] = None,
                 spec_ks: Optional[Sequence[int]] = None,
                 kv_dtype: Optional[str] = None,
                 kv_dtypes: Optional[Sequence[Optional[str]]] = None,
                 kv_guard_layers: Sequence[int] = (),
                 kvsan: bool = False):
        assert policy in ("continuous", "static"), policy
        assert cache_layout in ("contiguous", "paged"), cache_layout
        self.replicas = list(replicas)
        self.policy = policy
        self.cache_layout = cache_layout
        self.block_size = block_size
        # speculative decoding: a SpecConfig shared by every replica, with
        # optional PER-REPLICA depths (the scheduler's acceptance-aware
        # spec_ks — 0 disables speculation on that replica)
        if spec is not None and (cache_layout != "paged"
                                 or policy == "static"):
            warnings.warn(
                "speculative decoding needs policy='continuous' with "
                "cache_layout='paged' (multi-token verification runs "
                "through the paged context path); serving without it",
                stacklevel=2)
            spec = None
        if spec_ks is not None:
            assert len(spec_ks) == len(self.replicas), (spec_ks,)

        def replica_spec(i: int) -> Optional[SpecConfig]:
            if spec is None:
                return None
            if spec_ks is None:
                return spec
            return dataclasses.replace(spec, k=spec_ks[i]) \
                if spec_ks[i] >= 1 else None
        if (prefix_caching or prefill_chunk) and (
                cache_layout != "paged" or policy == "static"):
            warnings.warn(
                "prefix_caching / prefill_chunk need policy='continuous' "
                "with cache_layout='paged' (block-granular aliasing); "
                "serving without them", stacklevel=2)
            prefix_caching, prefill_chunk = False, 0
        # host page tier + cluster prefix directory: both are keyed by
        # prefix chunk hashes, so both need the prefix index. host_blocks
        # is one capacity for every replica or a per-replica sequence (the
        # scheduler's SearchResult.host_blocks — big host pools belong
        # next to small device pools).
        if host_blocks is None:
            host_blocks = 0
        if np.ndim(host_blocks) == 0:
            host_blocks = [int(host_blocks)] * len(self.replicas)
        else:
            host_blocks = [int(b) for b in host_blocks]
            assert len(host_blocks) == len(self.replicas), (host_blocks,)
        if (any(host_blocks) or cluster_prefix) and not prefix_caching:
            warnings.warn(
                "host_blocks / cluster_prefix need prefix_caching=True "
                "(the page tiers and the directory are keyed by prefix "
                "chunk hashes); serving without them", stacklevel=2)
            host_blocks = [0] * len(self.replicas)
            cluster_prefix = False
        self.host_blocks = host_blocks
        # quantized KV pages: ONE pool precision (`kv_dtype`) or the
        # scheduler's PER-REPLICA choices (`kv_dtypes`, None entry = model
        # default). Only the paged continuous engine has page pools.
        if (kv_dtype is not None or kv_dtypes is not None) and (
                cache_layout != "paged" or policy == "static"):
            warnings.warn(
                "kv_dtype needs policy='continuous' with "
                "cache_layout='paged' (precision is a page-pool layout); "
                "serving at model precision", stacklevel=2)
            kv_dtype, kv_dtypes = None, None
        if kv_dtypes is not None:
            assert len(kv_dtypes) == len(self.replicas), (kv_dtypes,)

        def replica_kv_dtype(i: int) -> Optional[str]:
            if kv_dtypes is not None and kv_dtypes[i] is not None:
                return kv_dtypes[i]
            return kv_dtype
        # disaggregated prefill/decode: role-tagged paged replicas + a KV
        # dispatcher wiring prefill workers to decode workers
        if roles is not None and any(r != "both" for r in roles):
            from repro.serving.pipeline import context_mode_supported
            if (cache_layout != "paged" or policy == "static"
                    or len(self.replicas) < 2):
                warnings.warn(
                    "disaggregated roles need policy='continuous' with "
                    "cache_layout='paged' and >= 2 replicas (the KV "
                    "handoff is a page transfer); serving colocated",
                    stacklevel=2)
                roles = None
            elif self.replicas and not context_mode_supported(
                    self.replicas[0].cfg):
                warnings.warn(
                    "disaggregation needs an attention-only stack "
                    "(recurrent running state has no pages to migrate); "
                    "serving colocated", stacklevel=2)
                roles = None
        self.roles = list(roles) if roles is not None \
            else ["both"] * len(self.replicas)
        assert len(self.roles) == len(self.replicas), (roles,)
        # a migrated page payload lands VERBATIM in the destination pool,
        # so a disaggregated group needs one uniform pool precision: the
        # narrowest chosen one wins (the capacity-constrained replica is
        # why precision dropped in the first place)
        if any(r != "both" for r in self.roles):
            chosen = {replica_kv_dtype(i) for i in range(len(self.replicas))}
            if len(chosen) > 1:
                uniform = next((d for d in ("int8", "fp8") if d in chosen),
                               None)
                warnings.warn(
                    "disaggregated replicas must share one KV pool "
                    f"precision (the page payload ships verbatim); using "
                    f"{uniform or 'model default'} everywhere",
                    stacklevel=2)
                kv_dtype, kv_dtypes = uniform, None
        if step_costs is None:
            step_costs = [1.0] * len(self.replicas)
        assert len(step_costs) == len(self.replicas)
        if kvsan and (cache_layout != "paged" or policy != "continuous"):
            warnings.warn(
                "kvsan sanitizes the paged KV lifecycle; "
                "policy='continuous' with cache_layout='paged' is "
                "required — serving unsanitized", stacklevel=2)
            kvsan = False
        if policy == "continuous" and cache_layout == "paged":
            self.workers = [PagedPipelineBatcher(
                r, n_slots=n_slots, max_len=max_len, pad_id=pad_id,
                block_size=block_size, stage_blocks=stage_blocks,
                prefix_caching=prefix_caching, prefill_chunk=prefill_chunk,
                prefill_token_cost=prefill_token_cost,
                host_blocks=host_blocks[i], host_swap_cost=host_swap_cost,
                virtual_step_cost=sc, role=role, replica_id=i,
                spec=replica_spec(i), kv_dtype=replica_kv_dtype(i),
                kv_guard_layers=kv_guard_layers, kvsan=kvsan)
                for i, (r, role, sc) in enumerate(
                    zip(self.replicas, self.roles, step_costs))]
            self.dispatcher = wire_disaggregation(self.workers, self.roles,
                                                  kv_link)
        elif policy == "continuous":
            self.workers = [PipelineBatcher(r, n_slots=n_slots,
                                            max_len=max_len, pad_id=pad_id,
                                            virtual_step_cost=sc)
                            for r, sc in zip(self.replicas, step_costs)]
            self.dispatcher = None
        else:
            if cache_layout == "paged":
                warnings.warn(
                    "cache_layout='paged' has no effect with "
                    "policy='static' (the whole-batch engine allocates "
                    "per-generate caches); serving contiguous",
                    stacklevel=2)
            self.workers = [StaticBatcher(r, max_batch=max_batch,
                                          pad_id=pad_id, max_len=max_len,
                                          virtual_step_cost=sc)
                            for r, sc in zip(self.replicas, step_costs)]
            self.dispatcher = None
        # every worker carries its replica id (deterministic least-loaded
        # tiebreaks, dispatcher targeting, directory residency keys)
        for i, w in enumerate(self.workers):
            w.replica_id = i
        # ---- cluster prefix directory + prefix-aware routing ------------
        self.cluster_dir = None
        if cluster_prefix:
            from repro.serving.cluster_kv import wire_cluster_prefix
            self.cluster_dir = wire_cluster_prefix(self.workers,
                                                   link=kv_link)
        self.prefix_route_weight = prefix_route_weight
        self.host_route_weight = host_route_weight
        self._route_rng = (np.random.RandomState(route_seed)
                           if route_seed is not None else None)
        # online rescheduling: an attached controller rides self.workers
        # as one more loop citizen (serving.resched.OnlineRescheduler) and
        # sees every admission for drift detection
        self.controller = None

    def attach_controller(self, controller) -> None:
        """Attach an online-rescheduling controller: it observes every
        dispatched request (drift detection) and, while ``serve`` runs,
        participates in the loop to execute kills/re-solves/migrations.
        ``self.workers`` is the LIVE membership list the controller
        mutates — the serve loop re-reads it every cycle."""
        self.controller = controller
        controller.bind(self)

    # ---- admission dispatch (serving.loop hook) --------------------------
    def _route_key(self, w, now: float):
        # deterministic tiebreak by replica id, or a seeded draw when the
        # caller wants reproducible-but-shuffled routing benchmarks
        tie = (self._route_rng.random() if self._route_rng is not None
               else getattr(w, "replica_id", 0))
        return w.load(now), tie

    def _dispatch(self, cands, req: Request, now: float):
        """Admission choice: least-loaded, minus a prefix-affinity bonus
        when the cluster directory knows a candidate already holds the
        prompt's head. Device-resident blocks count full (an alias costs
        nothing), host-resident ones at ``host_route_weight`` (a swap-in
        is cheaper than recompute but dearer than an alias), and the
        bonus is scaled by ``prefix_route_weight`` into queue-depth
        units — so a deep queue still beats a marginal prefix hit."""
        if self.controller is not None:
            self.controller.observe_admit(now, req)
        if self.cluster_dir is None or self.prefix_route_weight <= 0:
            return min(cands, key=lambda w: self._route_key(w, now))
        hashes = chunk_hashes(req.prompt, self.block_size)

        def key(w):
            load, tie = self._route_key(w, now)
            ndev, nhost = self.cluster_dir.resident_blocks(
                hashes, getattr(w, "replica_id", -1))
            bonus = ndev + self.host_route_weight * nhost
            return (load - self.prefix_route_weight * bonus, tie)
        return min(cands, key=key)

    def bind_tracer(self, tracer) -> None:
        """Hand every tracing-aware collaborator the live tracer: workers
        (span emission sites), the KV dispatcher (migration spans), and
        the controller (replica_kill instants). Workers created after a
        controller reschedule inherit it via ``replace_workers`` calling
        back through the controller's ``tracer`` attribute."""
        for w in self.workers:
            if hasattr(w, "tracer"):
                w.tracer = tracer
            prop = getattr(w, "_proposer", None)
            if prop is not None and hasattr(prop, "tracer"):
                prop.tracer = tracer
        if self.dispatcher is not None:
            self.dispatcher.tracer = tracer
        if self.controller is not None:
            self.controller.tracer = tracer

    def serve(self, requests: Sequence[Request], deadline: float, *,
              clock=None, tracer=None, metrics=None) -> ServeStats:
        """Replays a timed workload; wall-clock by default, or any Clock
        (e.g. VirtualClock for deterministic replay). An attached
        controller (``attach_controller``) joins ``self.workers`` for the
        replay — the SAME list object the loop re-reads each cycle, so
        the controller's membership edits (kills, re-solved layouts) are
        visible next iteration. A ``tracer`` (repro.obs.trace.Tracer) is
        bound to every worker for lifecycle spans; a ``metrics`` registry
        (repro.obs.metrics.MetricsRegistry) receives per-replica counters
        and pool gauges at the end of the replay."""
        if tracer is not None and tracer.enabled:
            self.bind_tracer(tracer)
        ctl = self.controller
        if ctl is not None and ctl not in self.workers:
            self.workers.append(ctl)
        try:
            return run_serve_loop(
                self.workers, requests, deadline=deadline,
                clock=clock if clock is not None else WallClock(),
                dispatch=self._dispatch, tracer=tracer, metrics=metrics)
        finally:
            if ctl is not None and ctl in self.workers:
                self.workers.remove(ctl)
