"""Task coordinator (paper Appendix C): dispatches requests to the scheduled
replica groups. Static batching per replica (Appendix D: HexGen has no
continuous batching; we batch waiting requests up to max_batch with left
padding)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class ServeStats:
    latencies: List[float]
    attainment: float
    throughput: float

    def summary(self) -> str:
        lat = np.asarray(self.latencies)
        return (f"n={len(lat)} p50={np.percentile(lat, 50):.3f}s "
                f"p99={np.percentile(lat, 99):.3f}s "
                f"slo={self.attainment * 100:.1f}% thpt={self.throughput:.2f} req/s")


class Router:
    """Least-loaded dispatch over replicas, mirroring the SLO simulator."""

    def __init__(self, replicas, *, max_batch: int = 4, pad_id: int = 0):
        self.replicas = list(replicas)
        self.max_batch = max_batch
        self.pad_id = pad_id
        self.next_free = [0.0] * len(self.replicas)

    def _run_batch(self, replica, batch: List[Request]):
        maxlen = max(len(r.prompt) for r in batch)
        toks = np.full((len(batch), maxlen), self.pad_id, np.int32)
        kv_start = np.zeros(len(batch), np.int32)
        for i, r in enumerate(batch):
            toks[i, maxlen - len(r.prompt):] = r.prompt        # left pad
            kv_start[i] = maxlen - len(r.prompt)
        max_new = max(r.max_new_tokens for r in batch)
        out = replica.generate(toks, max_new=max_new, kv_start=kv_start)
        for i, r in enumerate(batch):
            r.output = out[i, :r.max_new_tokens]

    def serve(self, requests: Sequence[Request], deadline: float) -> ServeStats:
        """Replays a timed workload measuring wall-clock latencies."""
        t0 = time.monotonic()
        pending = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        while idx < len(pending):
            now = time.monotonic() - t0
            # wait for the next arrival if nothing is due
            if pending[idx].arrival > now:
                time.sleep(min(pending[idx].arrival - now, 0.05))
                continue
            # batch everything that has arrived, up to max_batch
            batch = []
            while idx < len(pending) and len(batch) < self.max_batch \
                    and pending[idx].arrival <= now:
                batch.append(pending[idx])
                idx += 1
            r = int(np.argmin(self.next_free))
            self._run_batch(self.replicas[r], batch)
            fin = time.monotonic() - t0
            self.next_free[r] = fin
            for req in batch:
                req.start_time = now
                req.finish_time = fin
        lats = [r.latency for r in pending]
        att = float(np.mean([l <= deadline for l in lats])) if lats else 1.0
        dur = max(r.finish_time for r in pending) if pending else 1.0
        return ServeStats(latencies=lats, attainment=att,
                          throughput=len(pending) / max(dur, 1e-9))
