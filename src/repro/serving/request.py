"""Requests and synthetic workloads (Poisson arrivals, §5.1)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    start_time: float = 0.0
    finish_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival


def synth_workload(*, rate: float, duration: float, vocab: int,
                   prompt_len: int = 32, prompt_jitter: int = 8,
                   out_len: int = 16, seed: int = 0) -> List[Request]:
    """Poisson arrivals with near-uniform prompt lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        plen = prompt_len + int(rng.integers(0, prompt_jitter + 1))
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=out_len,
            arrival=t))
        rid += 1
    return reqs
