"""Requests and synthetic workloads (Poisson arrivals, §5.1)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (s,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0
    # filled by the engine; None means "never happened" — a request the
    # loop stranded keeps finish_time None and is accounted as dropped
    # (never as a negative latency)
    output: Optional[np.ndarray] = None
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    first_token_time: Optional[float] = None   # TTFT (prefix/chunk benches)
    # disaggregated serving: when the prefill replica handed the KV off
    # (first_token_time - prefill_finish_time = transfer + decode queueing)
    prefill_finish_time: Optional[float] = None

    @property
    def latency(self) -> float:
        """Only meaningful once finish_time is stamped; ServeStats guards."""
        return self.finish_time - self.arrival

    @property
    def served(self) -> bool:
        """Finished with the tokens it asked for (not rejected/stranded)."""
        if self.finish_time is None:
            return False
        if (self.output is not None and len(self.output) == 0
                and self.max_new_tokens > 0):
            return False               # rejected with an empty output
        return True


def synth_workload(*, rate: float, duration: float, vocab: int,
                   prompt_len: int = 32, prompt_jitter: int = 8,
                   out_len: int = 16, seed: int = 0) -> List[Request]:
    """Poisson arrivals with near-uniform prompt lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        plen = prompt_len + int(rng.integers(0, prompt_jitter + 1))
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=out_len,
            arrival=t))
        rid += 1
    return reqs


def shared_prefix_workload(*, rate: float, duration: float, vocab: int,
                           shared_len: int = 48, unique_len: int = 8,
                           unique_jitter: int = 4, out_len: int = 8,
                           n_prefixes: int = 1, seed: int = 0
                           ) -> List[Request]:
    """Poisson arrivals where every prompt = one of `n_prefixes` shared
    system prompts + a unique user tail — the multi-user regime where
    prefix caching deduplicates the dominant prefill cost (the system
    prompt is >= shared_len / (shared_len + unique_len) of every prompt).
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=shared_len).astype(np.int32)
                for _ in range(max(n_prefixes, 1))]
    reqs = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        tail_len = unique_len + int(rng.integers(0, unique_jitter + 1))
        tail = rng.integers(0, vocab, size=tail_len).astype(np.int32)
        prefix = prefixes[rid % len(prefixes)]
        reqs.append(Request(
            rid=rid,
            prompt=np.concatenate([prefix, tail]),
            max_new_tokens=out_len,
            arrival=t))
        rid += 1
    return reqs
