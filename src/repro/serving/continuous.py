"""Continuous (iteration-level) batching — the paper's acknowledged
limitation (Appendix D), implemented here as a beyond-paper extension.

A replica owns a fixed pool of decode SLOTS backed by one pre-allocated
cache. New requests are prefilled individually (batch=1) and their cache
rows scattered into a free slot between decode iterations; every iteration
decodes all active slots jointly with PER-SLOT positions; finished slots
free immediately. Attention/MoE/SSM state is row-independent, so a
request's outputs are bit-identical to isolated generation (tested).

Works for full-KV and recurrent-state architectures; SWA ring caches
require uniform positions and fall back to static batching (noted).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.request import Request


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0               # next write position
    remaining: int = 0
    out: Optional[list] = None


class ContinuousBatcher:
    """Single-replica continuous batching on one jax device (monolithic
    model apply; the asymmetric pipeline variant composes the same slot
    logic per stage)."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 256, key=None):
        assert not cfg.swa_window, \
            "SWA ring caches need uniform positions; use static batching"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.slots = [_Slot() for _ in range(n_slots)]
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        self._prefill = jax.jit(
            lambda p, batch, c: M.prefill(cfg, p, batch, c))
        self._last_logits = np.zeros((n_slots, cfg.vocab_size), np.float32)

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.rid < 0]

    @property
    def active(self) -> bool:
        return any(s.rid >= 0 for s in self.slots)

    def insert(self, req: Request) -> int:
        """Prefill req (batch=1) and scatter its cache row into a slot."""
        free = self.free_slots()
        assert free, "no free slot"
        slot = free[0]
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        small = M.init_cache(self.cfg, 1, self.max_len)
        logits, small = self._prefill(self.params, {"tokens": toks}, small)

        def put(big, row):
            return big.at[:, slot].set(row[:, 0])

        self.cache = jax.tree.map(put, self.cache, small)
        self._last_logits[slot] = np.asarray(logits[0])
        self.slots[slot] = _Slot(rid=req.rid, pos=len(req.prompt),
                                 remaining=req.max_new_tokens, out=[])
        return slot

    def step(self) -> Dict[int, List[int]]:
        """One joint decode iteration. Returns {rid: finished tokens} for
        requests that completed this step."""
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.rid >= 0:
                toks[i] = int(self._last_logits[i].argmax())
                pos[i] = s.pos
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(pos))
        logits = np.asarray(logits)
        done = {}
        for i, s in enumerate(self.slots):
            if s.rid < 0:
                continue
            s.out.append(int(toks[i]))
            s.pos += 1
            s.remaining -= 1
            self._last_logits[i] = logits[i]
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                done[s.rid] = s.out
                self.slots[i] = _Slot()
        return done

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request], *, deadline: float,
              realtime: bool = False):
        """Replays a workload. realtime=False: virtual clock (arrival order
        respected, no sleeps) for deterministic tests."""
        from repro.serving.router import ServeStats
        pending = sorted(requests, key=lambda r: r.arrival)
        idx = 0
        t0 = time.monotonic()
        while idx < len(pending) or self.active:
            now = time.monotonic() - t0
            while (idx < len(pending) and self.free_slots()
                   and (pending[idx].arrival <= now or not realtime)):
                self.insert(pending[idx])
                idx += 1
            if realtime and not self.active and idx < len(pending):
                time.sleep(min(pending[idx].arrival - now, 0.05))
                continue
            if self.active:
                done = self.step()
                fin = time.monotonic() - t0
                for r in pending:
                    if r.rid in done:
                        r.output = np.asarray(done[r.rid], np.int32)
                        r.finish_time = fin
        lats = [r.latency for r in pending]
        att = float(np.mean([l <= deadline for l in lats])) if lats else 1.0
        dur = max((r.finish_time for r in pending), default=1.0)
        return ServeStats(latencies=lats, attainment=att,
                          throughput=len(pending) / max(dur, 1e-9))
