"""Continuous (iteration-level) batching — the paper's acknowledged
limitation (Appendix D), implemented here as a beyond-paper extension.

A replica owns a fixed pool of decode SLOTS backed by pre-allocated caches.
Requests admitted by the serve loop are buffered until the next iteration
boundary, then prefilled JOINTLY (one right-padded batch with per-row real
lengths) and their cache rows scattered into free slots; every iteration
decodes all slots jointly with PER-SLOT positions; finished slots free
immediately. Right padding keeps each row's token positions identical to
isolated generation and attention/MoE/SSM state is row-independent, so a
request's outputs are bit-identical to isolated generation (tested).

Two executors share the slot engine:

  * ``ContinuousBatcher``  — the monolithic single-process model apply
    (one cache pool for the whole stack);
  * ``PipelineBatcher``    — an ``AsymmetricPipeline`` replica (per-STAGE
    cache pools, so a multi-stage heterogeneous replica serves at iteration
    granularity end to end).

Works for full-KV and recurrent-state architectures; SWA ring caches
require uniform positions and fall back to static batching (noted).

Both implement the replica port of ``serving.loop`` — scheduling, clocking
and accounting live there, not here.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.obs.trace import NULL_TRACER
from repro.serving.block_manager import (BlockPool, BlockTable, HostPagePool,
                                         PrefixIndex, blocks_for_tokens,
                                         chunk_hashes)
from repro.serving.disagg import KVLink, KVMigration
from repro.serving.loop import (ServeStats, VirtualClock, WallClock,
                                run_serve_loop)
from repro.serving.request import Request
from repro.serving.spec import SpecConfig, greedy_accept


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0               # next write position (tokens cached so far)
    remaining: int = 0
    out: Optional[list] = None
    seq: int = 0               # admission order (paged preemption victims)
    # incremental-prefill state (prefix caching / chunked prefill): tokens
    # of the prompt not yet prefilled; None once decode can start
    pending: Optional[np.ndarray] = None
    hashes: Optional[list] = None   # full-block chunk hashes of the prompt
    matched: bool = False           # prefix lookup ran (lazily, first chunk)

    @property
    def free(self) -> bool:
        return self.req is None

    @property
    def decoding(self) -> bool:
        """Occupied and past prefill: participates in decode iterations."""
        return self.req is not None and self.pending is None


class SlotEngine:
    """Slot bookkeeping + the joint insert/decode iteration, shared by the
    monolithic and pipeline executors. Subclasses provide:

      _prefill_insert(toks (b,P), lens (b,), slot_ids) -> logits (m, V)
          where m = len(slot_ids) <= b; rows beyond m are compile-shape
          padding to be dropped before the cache scatter
      _decode_all(toks (n_slots,), pos (n_slots,))     -> logits (n_slots, V)
    """

    def __init__(self, *, n_slots: int, max_len: int, vocab_size: int,
                 pad_id: int = 0, virtual_step_cost: float = 1.0):
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self.virtual_step_cost = virtual_step_cost
        # HexTrace: the Router (or a test) swaps in a live Tracer; the
        # null default keeps every emission site a single attribute check
        self.tracer = NULL_TRACER
        self.replica_id = 0
        self.slots = [_Slot() for _ in range(n_slots)]
        self._queue: Deque[Request] = deque()
        self._last_logits = np.zeros((n_slots, vocab_size), np.float32)
        self.rejected = 0          # oversized requests turned away
        self.preemptions = 0       # paged: slots recomputed after eviction
        self._admit_seq = 0

    # ---- slot state ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    @property
    def active(self) -> bool:
        return any(not s.free for s in self.slots)

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        return max(len(self.free_slots()) - len(self._queue), 0)

    def load(self, now: float) -> float:
        return (self.n_slots - len(self.free_slots())) + len(self._queue)

    def admit(self, reqs: Sequence[Request], now: float) -> None:
        self._queue.extend(reqs)

    def busy(self, now: float) -> bool:
        return bool(self._queue) or self.active

    def inflight(self) -> int:
        return len(self._queue) + (self.n_slots - len(self.free_slots()))

    def next_event(self, now: float):
        return None                # compute worker: work runs when busy

    def run_iteration(self, now: float):
        """Insert buffered admissions, then one joint decode iteration."""
        comps = []
        free = self.free_slots()
        if self._queue and free:
            batch = []
            while self._queue and len(batch) < len(free):
                r = self._queue[0]
                # a request must fit prompt + all its decode steps on this
                # engine (slot length, and for the paged engine the whole
                # block pool); reject it alone (empty output, counted in
                # ServeStats.rejected) instead of crashing the serve loop
                # and losing every in-flight request
                if not self._fits(r):
                    self._queue.popleft()
                    self.rejected += 1
                    warnings.warn(
                        f"request {r.rid}: prompt {len(r.prompt)} + "
                        f"max_new {r.max_new_tokens} cannot fit this "
                        "engine; rejected with empty output")
                    comps.append((r, np.zeros(0, np.int32), None))
                    continue
                # admissible later but not right now (paged: not enough
                # free blocks yet) — keep it queued, FIFO order intact
                if not self._can_admit(r, batch):
                    break
                self._queue.popleft()
                batch.append(r)
            if batch:
                self._insert_batch(batch, free[:len(batch)])
        # nothing active (e.g. a rejection-only cycle): no decode to run —
        # and possibly no caches allocated yet to run it on
        done = self._step(now) if self.active else []
        comps.extend((req, np.asarray(out, np.int32), None)
                     for req, out in done)
        return comps, self.virtual_step_cost

    # ---- admission / paging hooks (overridden by the paged engine) --------
    def _fits(self, r: Request) -> bool:
        return len(r.prompt) + r.max_new_tokens <= self.max_len - 1

    def _can_admit(self, r: Request, batch: Sequence[Request]) -> bool:
        return True

    def _step(self, now: float):
        """One compute step once admissions are placed. The paged engine
        overrides this to interleave prefill chunks with the decode."""
        return self._decode_iteration(now)

    def _before_decode(self) -> None:
        pass                       # paged: allocate-on-decode / preemption

    def _on_slot_free(self, i: int) -> None:
        pass                       # paged: release the slot's block tables

    # ---- engine internals ------------------------------------------------
    def _insert_batch(self, reqs: Sequence[Request],
                      slot_ids: Sequence[int]) -> None:
        m = len(reqs)
        lens = np.asarray([len(r.prompt) for r in reqs], np.int32)
        assert int(lens.max()) < self.max_len, "prompt exceeds slot length"
        # bucket BOTH jit shape axes — padded width to multiples of 16,
        # insert count to the next power of two (capped at n_slots) — so a
        # bursty serve window compiles O(log) prefill shapes instead of one
        # per distinct (m, P) pair. Pad rows (and right pads) are masked in
        # the model and dropped by _prefill_insert before the scatter.
        P = min(-(-int(lens.max()) // 16) * 16, self.max_len - 1)
        m_pad = min(1 << (m - 1).bit_length(), self.n_slots)
        toks = np.full((m_pad, P), self.pad_id, np.int32)
        plens = np.ones((m_pad,), np.int32)
        plens[:m] = lens
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt                   # right pad
        logits = self._prefill_insert(toks, plens, list(slot_ids))
        if self.tracer.enabled:
            # one-shot joint prefill: every admitted prompt completes its
            # prefill within this iteration
            ntok = int(lens.sum())
            self.tracer.complete(
                "prefill",
                self.virtual_step_cost
                * getattr(self, "prefill_token_cost", 0.0) * ntok,
                pid=self.replica_id, tokens=ntok, slots=m)
            for r in reqs:
                self.tracer.mark(r.rid, "prefill_finish",
                                 self.tracer.now())
        for i, (r, slot) in enumerate(zip(reqs, slot_ids)):
            self._last_logits[slot] = np.asarray(logits[i])
            self.slots[slot] = _Slot(req=r, pos=int(lens[i]),
                                     remaining=r.max_new_tokens, out=[],
                                     seq=self._admit_seq)
            self._admit_seq += 1

    def _decode_iteration(self, now: float = 0.0):
        self._before_decode()      # paged: grow tables, maybe preempt
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        n_dec = 0
        for i, s in enumerate(self.slots):
            if s.decoding:         # mid-prefill slots sit this one out
                toks[i] = int(self._last_logits[i].argmax())
                pos[i] = s.pos
                n_dec += 1
        logits = self._decode_all(toks, pos)
        if self.tracer.enabled and n_dec:
            # one joint decode step; its virtual cost is the flat
            # iteration cost whatever the batch width
            self.tracer.complete("decode", self.virtual_step_cost, ts=now,
                                 pid=self.replica_id, tokens=n_dec)
        done = []
        for i, s in enumerate(self.slots):
            if not s.decoding:
                continue
            s.out.append(int(toks[i]))
            if len(s.out) == 1 and s.req is not None:
                # first-wins: a preempt-recompute re-produces the token
                # stream, but the client saw the first token at the
                # ORIGINAL emission (trace marks share this discipline)
                if s.req.first_token_time is None:
                    s.req.first_token_time = now
                self.tracer.mark(s.req.rid, "first_token", now)
            s.pos += 1
            s.remaining -= 1
            self._last_logits[i] = logits[i]
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                done.append((s.req, s.out))
                self._on_slot_free(i)
                self.slots[i] = _Slot()
        return done

    def _prefill_insert(self, toks, lens, slot_ids):
        raise NotImplementedError

    def _decode_all(self, toks, pos):
        raise NotImplementedError

    # ---- single-replica convenience (shared loop underneath) --------------
    def serve(self, requests: Sequence[Request], *, deadline: float,
              realtime: bool = False) -> ServeStats:
        """Replays a workload on this replica alone. realtime=False uses the
        virtual clock: deterministic latencies in iteration units."""
        clock = WallClock() if realtime else VirtualClock()
        return run_serve_loop([self], requests, deadline=deadline,
                              clock=clock,
                              tracer=(self.tracer if self.tracer.enabled
                                      else None))

    # seed-API shims (tests, notebooks) ------------------------------------
    def insert(self, req: Request) -> int:
        """Immediate single insert; returns the slot index."""
        free = self.free_slots()
        assert free, "no free slot"
        self._insert_batch([req], free[:1])
        return free[0]

    def step(self) -> Dict[int, List[int]]:
        """One engine step (prefill chunks where pending, then the joint
        decode — identical to what run_iteration drives). Returns
        {rid: finished tokens}."""
        return {req.rid: out for req, out in self._step(0.0)}


class ContinuousBatcher(SlotEngine):
    """Slot-based continuous batching on the monolithic model apply (single
    jit over the full stack; the asymmetric-pipeline variant is
    ``PipelineBatcher``)."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 256, pad_id: int = 0, key=None,
                 virtual_step_cost: float = 1.0):
        from repro.serving.pipeline import slot_mode_supported
        assert slot_mode_supported(cfg), \
            "slot mode needs uniform text decode; use static batching"
        super().__init__(n_slots=n_slots, max_len=max_len,
                         vocab_size=cfg.vocab_size, pad_id=pad_id,
                         virtual_step_cost=virtual_step_cost)
        self.cfg = cfg
        self.params = params
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        self._prefill = jax.jit(
            lambda p, toks, lens, c: M.prefill(cfg, p, {"tokens": toks}, c,
                                               lens=lens))

    def _prefill_insert(self, toks, lens, slot_ids):
        m = len(slot_ids)          # toks may carry compile-padding rows > m
        scratch = M.init_cache(self.cfg, toks.shape[0], self.max_len)
        logits, scratch = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(lens), scratch)
        # monolithic cache leaves are period-stacked: batch axis is 1
        rows = jax.tree.map(lambda l: l[:, :m], scratch)
        self.cache = M.scatter_cache_rows(self.cache, rows, slot_ids,
                                          batch_axis=1)
        return np.asarray(logits)[:m]

    def _decode_all(self, toks, pos):
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(pos))
        return np.asarray(logits)


class PipelineBatcher(SlotEngine):
    """Slot-based continuous batching over an ``AsymmetricPipeline``
    replica: per-stage cache pools, iteration-level joint decode with
    per-slot positions, joint right-padded insert prefill."""

    def __init__(self, pipeline, *, n_slots: int = 8, max_len: int = 256,
                 pad_id: int = 0, virtual_step_cost: float = 1.0):
        from repro.serving.pipeline import slot_mode_supported
        assert slot_mode_supported(pipeline.cfg), \
            "slot mode needs uniform text decode; use StaticBatcher"
        super().__init__(n_slots=n_slots, max_len=max_len,
                         vocab_size=pipeline.cfg.vocab_size, pad_id=pad_id,
                         virtual_step_cost=virtual_step_cost)
        self.pipeline = pipeline

    def _prefill_insert(self, toks, lens, slot_ids):
        # pools allocate lazily so generate()-only engines never pay for them
        if (self.pipeline.slot_caches is None
                or self.pipeline.n_slots != self.n_slots
                or self.pipeline.slot_len != self.max_len):
            self.pipeline.init_slot_caches(self.n_slots, self.max_len)
        return self.pipeline.insert_slots(toks, lens, slot_ids)

    def _decode_all(self, toks, pos):
        return self.pipeline.decode_slots(toks, pos)


class PagedPipelineBatcher(SlotEngine):
    """Slot-based continuous batching over an ``AsymmetricPipeline`` with a
    PAGED KV cache: each stage owns a block pool sized independently
    (``stage_blocks``, ∝ its devices' memory — the asymmetric-capacity
    point), requests hold per-stage BlockTables, admission requires enough
    free blocks for the prompt plus headroom rather than a worst-case
    ``max_len`` row, decode grows tables one block at a time, and a dry
    pool preempts the youngest slot by recompute (blocks freed, request
    requeued at the front; greedy decode regenerates the same tokens).

    ``max_len`` remains the per-request ceiling (block tables hold
    max_len / block_size entries); what paging removes is the RESERVATION:
    a slot only ever occupies the blocks its tokens actually fill, so a
    pool sized for actual usage serves far more concurrent slots than
    max_len-row pre-allocation (benchmarks/bench_paged.py).

    ``prefix_caching=True`` cashes in the refcounts: each stage keeps a
    ``PrefixIndex`` (hash of block-aligned prompt chunks -> resident
    block), admission aliases a new prompt's longest indexed prefix
    (fork-style incref) and prefills only the COLD SUFFIX through the
    paged context path (pipeline.context_slots_paged); a write landing in
    a still-shared block copies it first (BlockTable.writable +
    pipeline.copy_pages). Cached blocks outlive their request — one index
    reference each — and are evicted LRU-first when a pool runs dry.

    ``prefill_chunk=N`` splits any prefill longer than N tokens into
    N-token chunks run one per iteration, so a giant prompt no longer
    stalls every in-flight decode for its whole prefill (iteration-level
    fairness). Both switches need an attention-only stack
    (pipeline.context_mode_supported): recurrent state is a running
    summary — nothing to alias per block, nothing to resume per chunk.

    ``prefill_token_cost`` (virtual clock only) charges each prefilled
    token that fraction of an iteration, so chunking and prefix hits show
    up in simulated TTFT/latency instead of hiding behind a flat
    per-iteration cost; 0.0 keeps the PR-2 flat-cost accounting.

    ``host_blocks > 0`` adds a HOST-MEMORY PAGE TIER (needs prefix
    caching): ``PrefixIndex`` eviction under pool pressure DEMOTES a
    prefix block's page payload into a per-stage ``HostPagePool`` (at
    pool precision — quantized pages spill narrow) instead of deleting
    it, and a later prompt that matches past the device-resident prefix
    PROMOTES pages back into fresh device blocks, block by block, so the
    shared-prefix working set survives a device pool too small to hold
    it. Preempt-by-recompute recovers through the same path: the victim's
    registered prefix demotes under the very pressure that evicted it and
    swaps back in at re-admission instead of re-prefilling.
    ``host_swap_cost`` (virtual clock) charges each block moved across
    the device<->host boundary that fraction of an iteration.

    ``attach_cluster`` (serving.cluster_kv.wire_cluster_prefix) joins a
    CLUSTER PREFIX DIRECTORY: the replica publishes its (hash -> tier)
    residency, and a prompt whose prefix lives only on a PEER replica
    fetches those pages over the KV link — the PR-4 ``KVMigration`` wire
    format (per-global-layer payloads) charged at ``KVLink.delay`` on the
    serving clock — before falling back to cold prefill. Token streams
    never depend on the directory: a stale entry just costs recompute.

    ``role`` splits the two inference phases across replicas (disaggregated
    serving, serving.disagg):

      * "both"    — colocated serving, the default: prefill and decode on
        this replica.
      * "prefill" — this replica only prefills. A slot whose prompt is
        fully cached is EXTRACTED (page payloads + cached token count +
        last logits) and handed to ``self.dispatcher`` instead of
        decoding; its blocks free immediately (index-registered prefix
        blocks stay resident for future prompts). The router never needs
        to know: completions simply arrive from the decode replica.
      * "decode"  — this replica admits no fresh arrivals
        (``capacity() == 0``); work arrives via ``migrate_in`` as
        in-transit migrations that land in free slots once their transfer
        delay elapses, resuming decode from the migrated pages and logits
        bit-identically to colocated serving. A preempted migrated slot
        falls back to local recompute (this is still a full replica).

    Disaggregation needs an attention-only stack: KV pages are the whole
    per-request state, so the handoff is a page transfer; recurrent
    running state has no page identity to ship.

    ``spec`` (a ``serving.spec.SpecConfig``) turns on SPECULATIVE
    DECODING: each decode iteration becomes a draft-then-verify step —
    a proposer (prompt-lookup n-grams, or a small draft model) guesses up
    to ``spec.k`` candidate tokens per slot, the target verifies the
    bonus token plus all candidates in ONE multi-token pipeline step
    (``pipeline.verify_slots_paged``), greedy acceptance commits the
    longest candidate prefix matching the target's argmax chain (1 to
    k + 1 tokens per step), and the speculative pages past the committed
    length roll back onto the pool (``BlockTable.truncate``). The
    committed stream is token-identical to plain greedy decode at any
    acceptance rate; only the step count changes. Needs an attention-only
    stack (the verification chunk cannot be rolled back through recurrent
    state); composes with prefix caching, chunked prefill, preemption and
    disaggregated decode replicas.
    """

    def __init__(self, pipeline, *, n_slots: int = 8, max_len: int = 256,
                 block_size: int = 16,
                 stage_blocks: Optional[Sequence[int]] = None,
                 admit_headroom: Optional[int] = None, pad_id: int = 0,
                 virtual_step_cost: float = 1.0,
                 prefix_caching: bool = False, prefill_chunk: int = 0,
                 prefill_token_cost: float = 0.0,
                 host_blocks: int = 0, host_swap_cost: float = 0.0,
                 role: str = "both", replica_id: int = 0,
                 spec: Optional[SpecConfig] = None,
                 kv_dtype: Optional[str] = None,
                 kv_guard_layers: Sequence[int] = (),
                 kvsan: bool = False):
        from repro.serving.pipeline import (context_mode_supported,
                                            slot_mode_supported)
        assert slot_mode_supported(pipeline.cfg), \
            "slot mode needs uniform text decode; use StaticBatcher"
        assert max_len % block_size == 0, (max_len, block_size)
        assert role in ("both", "prefill", "decode"), role
        if role != "both":
            assert context_mode_supported(pipeline.cfg), \
                "disaggregation needs an attention-only stack (recurrent " \
                "running state has no pages to migrate)"
        if ((prefix_caching or prefill_chunk)
                and not context_mode_supported(pipeline.cfg)):
            warnings.warn(
                f"{pipeline.cfg.name}: prefix caching / chunked prefill "
                "need an attention-only stack (recurrent state has no "
                "per-block identity); serving without them", stacklevel=2)
            prefix_caching, prefill_chunk = False, 0
        super().__init__(n_slots=n_slots, max_len=max_len,
                         vocab_size=pipeline.cfg.vocab_size, pad_id=pad_id,
                         virtual_step_cost=virtual_step_cost)
        self.pipeline = pipeline
        self.block_size = block_size
        self.max_blocks = max_len // block_size
        # paged-pool storage precision ("fp32"/"bf16"/"int8"/"fp8"; None =
        # model default). Quantized pools need the paged CONTEXT/VERIFY
        # write paths, which exist for attention-only stacks only.
        from repro.models import quant as Q
        if kv_dtype is not None and Q.kv_is_quantized(kv_dtype) \
                and not context_mode_supported(pipeline.cfg):
            warnings.warn(
                f"{pipeline.cfg.name}: quantized KV pages need an "
                "attention-only stack (recurrent slot state has no paged "
                "rows to quantize); serving at model precision",
                stacklevel=2)
            kv_dtype = None
        self.kv_dtype = kv_dtype
        self.kv_guard_layers = tuple(kv_guard_layers)
        # tokens of decode headroom a request must find free at admission
        self.admit_headroom = (block_size if admit_headroom is None
                               else admit_headroom)
        full = n_slots * self.max_blocks + 1
        if stage_blocks is None:
            stage_blocks = [full] * len(pipeline.stages)
        self.stage_blocks = list(stage_blocks)
        assert len(self.stage_blocks) == len(pipeline.stages)
        # host-side bookkeeping exists from construction (capacity() needs
        # it before any insert); device page arrays allocate lazily
        self._pools: List[Optional[BlockPool]] = []
        self._tables: List[Optional[List[BlockTable]]] = []
        for st, nb in zip(pipeline.stages, self.stage_blocks):
            if st.has_attn:
                pool = BlockPool(nb, block_size)
                self._pools.append(pool)
                self._tables.append([BlockTable(pool)
                                     for _ in range(n_slots)])
            else:
                self._pools.append(None)
                self._tables.append(None)
        # ---- KVSAN: opt-in page-lifecycle sanitizer --------------------
        # (repro.analysis.kvsan) shadows every pool's alloc/incref/free,
        # tracks kernel write/read coverage per block, and audits refcount
        # conservation each iteration. Pure observation: token streams
        # are identical with it on or off.
        self.kvsan = bool(kvsan)
        self.kvsan_leaks = 0
        self._san = None
        if self.kvsan:
            from repro.analysis.kvsan import KVSanitizer
            self._san = KVSanitizer(
                quant=(self.kv_dtype is not None
                       and Q.kv_is_quantized(self.kv_dtype)))
            for si, p in enumerate(self._pools):
                if p is not None:
                    self._san.attach_pool(si, p)
        # typical next-request footprint for the capacity() port, learned
        # from admitted traffic (start at one block)
        self._need_sum = 0
        self._need_cnt = 0
        # per-stage stacked block-table arrays for the decode hot path;
        # rebuilt only when a table mutates (insert / growth / release)
        self._bt_cache: Optional[List[np.ndarray]] = None
        # ---- prefix caching / chunked prefill --------------------------
        self.prefix_caching = prefix_caching
        self.prefill_chunk = int(prefill_chunk)
        self.prefill_token_cost = prefill_token_cost
        # incremental mode routes prompts through the per-slot context
        # path instead of the joint one-shot insert
        self._incremental = prefix_caching or self.prefill_chunk > 0
        self._prefix: List[Optional[PrefixIndex]] = [
            PrefixIndex(p) if (prefix_caching and p is not None) else None
            for p in self._pools]
        # ---- host page tier (device -> host demotion) ------------------
        if host_blocks and not self.prefix_caching:
            warnings.warn(
                "host_blocks needs prefix_caching=True (the host tier is "
                "keyed by prefix chunk hashes); serving without a host "
                "tier", stacklevel=2)
            host_blocks = 0
        self.host_blocks = int(host_blocks)
        self.host_swap_cost = host_swap_cost
        self._host: List[Optional[HostPagePool]] = [
            HostPagePool(self.host_blocks, block_size)
            if (self.host_blocks > 0 and p is not None) else None
            for p in self._pools]
        # the first attention stage is the cluster directory's
        # REPRESENTATIVE: tier transitions publish once per hash, not once
        # per stage (stages register/evict near-symmetrically; the
        # directory is a hint and export verifies every stage anyway)
        self._rep_stage = next(
            (si for si, p in enumerate(self._pools) if p is not None), None)
        for si, (ix, host) in enumerate(zip(self._prefix, self._host)):
            if ix is not None and host is not None:
                ix.spill = self._make_spill(si)
                host.on_evict = self._make_host_drop(si)
        if self._san is not None:
            # after the on_evict wiring so the sanitizer's LRU-drop
            # shadowing chains onto (not replaces) the directory hook
            for si, host in enumerate(self._host):
                if host is not None:
                    self._san.attach_host(si, host)
        # ---- cluster prefix directory (attach_cluster wires these) -----
        self.cluster_dir = None
        self.cluster_link: Optional[KVLink] = None
        self._cluster_peers: Dict[int, "PagedPipelineBatcher"] = {}
        # ---- disaggregated prefill/decode ------------------------------
        self.role = role
        self.replica_id = replica_id
        # set by serving.disagg.wire_disaggregation (role="prefill" only)
        self.dispatcher = None
        # in-transit migrations: heap of (ready_time, seq, KVMigration)
        self._migrations: List = []
        self._mig_seq = 0
        # ---- speculative decoding --------------------------------------
        self.spec = spec
        self._proposer = None
        if spec is not None and not context_mode_supported(pipeline.cfg):
            warnings.warn(
                f"{pipeline.cfg.name}: speculative decoding needs an "
                "attention-only stack (a recurrent sublayer's state cannot "
                "roll back past a rejected candidate); serving without it",
                stacklevel=2)
            self.spec = None
        if self.spec is not None:
            self._proposer = self.spec.build(
                n_slots=n_slots, max_len=max_len,
                vocab_size=pipeline.cfg.vocab_size, pad_id=pad_id)
        # counters surfaced through ServeStats (loop reports deltas)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0
        self.cow_copies = 0
        self.migrations = 0            # prefills handed off (sender side)
        self.migrated_kv_bytes = 0     # payload bytes shipped (sender side)
        self.spec_steps = 0            # target multi-token verify steps
        self.spec_proposed = 0         # draft tokens proposed
        self.spec_accepted = 0         # draft tokens the target agreed with
        self.spec_tokens = 0           # tokens committed via verify steps
        self.kv_bytes_resident = 0     # allocated page-pool bytes (+scales)
        self.kv_bytes_saved = 0        # vs the model-default-dtype layout
        self.host_demotions = 0        # blocks spilled device -> host
        self.host_promotions = 0       # blocks swapped back host -> device
        self.host_evictions = 0        # host-tier LRU drops (pages lost)
        self.host_hit_tokens = 0       # prompt tokens served from host tier
        self.prefix_fetches = 0        # prefix blocks fetched from peers
        self.prefix_fetched_bytes = 0  # payload bytes shipped for fetches
        self._iter_prefill_tokens = 0
        self._iter_spec_proposed = 0
        self._iter_swap_blocks = 0
        self._iter_fetch_cost = 0.0

    # ---- block accounting -------------------------------------------------
    def _min_pool_free(self) -> int:
        # cached-prefix blocks held only by the index are reclaimable on
        # demand (LRU eviction), so admission counts them as free
        frees = [p.n_free + (ix.n_evictable() if ix is not None else 0)
                 for p, ix in zip(self._pools, self._prefix)
                 if p is not None]
        return min(frees) if frees else 1 << 30

    def _usable_blocks(self) -> int:
        sizes = [p.n_blocks - 1 for p in self._pools if p is not None]
        return min(sizes) if sizes else 1 << 30

    def _blocks_needed(self, r: Request) -> int:
        """Admission footprint: prompt + decode headroom (not worst case)."""
        toks = len(r.prompt) + min(self.admit_headroom, r.max_new_tokens)
        return blocks_for_tokens(toks, self.block_size)

    def _typical_blocks(self) -> int:
        if self._need_cnt == 0:
            return 1
        return -(-self._need_sum // self._need_cnt)

    # ---- replica port -----------------------------------------------------
    def capacity(self, now: float) -> int:
        """Admission switches from "free slot" to "enough blocks": the loop
        may only hand us another request if, beyond the queued ones' needs,
        a typical request's prompt + headroom still fits every stage
        pool. A decode-role replica admits NO fresh arrivals — its work
        arrives as migrations."""
        if self.role == "decode":
            return 0
        slots = len(self.free_slots()) - len(self._queue)
        if slots <= 0:
            return 0
        queued = sum(self._blocks_needed(r) for r in self._queue)
        if self._min_pool_free() < queued + self._typical_blocks():
            return 0
        return slots

    def load(self, now: float) -> float:
        # in-transit migrations are queue depth too: the dispatcher picks
        # decode replicas by this number
        return super().load(now) + len(self._migrations)

    def busy(self, now: float) -> bool:
        if super().busy(now):
            return True
        return bool(self._migrations) and self._migrations[0][0] <= now

    def inflight(self) -> int:
        return super().inflight() + len(self._migrations)

    def next_event(self, now: float):
        # earliest in-transit migration arrival: the idle loop must jump
        # there, not strand the request
        if self._migrations and self._migrations[0][0] > now:
            return self._migrations[0][0]
        return None

    def metrics_gauges(self):
        """Gauge snapshot for the loop's metrics publication: per-stage
        device-pool occupancy (current + high-water) and host-tier
        residency."""
        out = []
        for si, (pool, host) in enumerate(zip(self._pools, self._host)):
            if pool is None:
                continue
            st = {"stage": si}
            out.append(("kv_pool_used_blocks", st, pool.n_used))
            out.append(("kv_pool_peak_blocks", st, pool.peak_used))
            if host is not None:
                out.append(("host_pool_used_blocks", st, len(host)))
        return out

    # ---- KV migration (disaggregated prefill/decode) -----------------------
    def migrate_in(self, mig: KVMigration, ready: float) -> None:
        """Accept a finished prefill from another replica; it becomes
        placeable once the serving clock reaches `ready` (the modeled
        transfer completion)."""
        assert mig.block_size == self.block_size, \
            (mig.block_size, self.block_size)
        heapq.heappush(self._migrations, (ready, self._mig_seq, mig))
        self._mig_seq += 1

    def _place_migrations(self, now: float) -> List:
        """Land every arrived migration a free slot + blocks can take:
        allocate each stage's blocks, scatter the page payloads, and seed
        the slot at the migrated position with the migrated sampling state
        — the next decode iteration continues exactly where the prefill
        replica stopped. Returns reject completions (a migration whose
        full generation can never fit this replica's pools)."""
        comps: List = []
        while self._migrations and self._migrations[0][0] <= now:
            mig = self._migrations[0][2]
            r = mig.req
            # a LIVE migration (online rescheduler moving a mid-decode
            # slot) arrives with the tokens the source already emitted;
            # the destination owes only the remainder of the generation
            out = list(mig.out_tokens) if mig.out_tokens is not None \
                else []
            remaining = r.max_new_tokens - len(out)
            need_all = blocks_for_tokens(
                mig.n_tokens + remaining, self.block_size)
            if need_all > self._usable_blocks() \
                    or mig.n_tokens + remaining > self.max_len - 1:
                heapq.heappop(self._migrations)
                self.rejected += 1
                warnings.warn(
                    f"request {r.rid}: migrated KV ({mig.n_tokens} tokens) "
                    f"+ {remaining} more cannot fit this decode "
                    "replica; rejected with empty output")
                comps.append((r, np.zeros(0, np.int32), None))
                continue
            free = self.free_slots()
            need_now = blocks_for_tokens(
                mig.n_tokens + min(self.admit_headroom, remaining),
                self.block_size)
            if not free or self._min_pool_free() < need_now:
                break                  # wait for slots/blocks to free
            heapq.heappop(self._migrations)
            if remaining <= 0:
                # the source extracted a slot that had already emitted its
                # whole budget: nothing left to decode, complete it here
                comps.append((r, np.asarray(out, np.int32), None))
                continue
            self._ensure_device_caches()
            slot = free[0]
            dest = []
            for si, tabs in enumerate(self._tables):
                if tabs is None:
                    dest.append(None)
                    continue
                t = tabs[slot]
                assert not t.blocks, "slot freed without releasing blocks"
                ok = self._stage_alloc(si, t, mig.n_tokens)
                assert ok, "placement checked free blocks yet ran dry"
                dest.append(list(t.blocks))
            self.pipeline.scatter_kv_pages(dest, mig.layer_kv)
            if self._san is not None:
                for si, d in enumerate(dest):
                    if d is not None:
                        self._san.slot_access(si, d, mig.n_tokens, 0,
                                              self.block_size)
            self.slots[slot] = _Slot(req=r, pos=mig.n_tokens,
                                     remaining=remaining, out=out,
                                     seq=self._admit_seq)
            self._admit_seq += 1
            self._last_logits[slot] = mig.last_logits
            self._bt_cache = None
        return comps

    def _migrate_ready(self, now: float) -> None:
        """Hand every prefill-complete slot to the dispatcher: extract its
        pages and sampling state, free its blocks (index-registered prefix
        blocks stay resident), and clear the slot. Oldest first, so
        dispatch order matches admission order."""
        assert self.dispatcher is not None, \
            "role='prefill' needs wire_disaggregation to set a dispatcher"
        order = sorted((i for i, s in enumerate(self.slots)
                        if s.decoding), key=lambda i: self.slots[i].seq)
        for i in order:
            s = self.slots[i]
            blocks = [list(tabs[i].blocks) if tabs is not None else None
                      for tabs in self._tables]
            if self._san is not None:
                for si, b in enumerate(blocks):
                    if b is not None:   # pure read: the handoff extraction
                        self._san.slot_access(si, b, s.pos, s.pos,
                                              self.block_size)
            layer_kv = self.pipeline.extract_kv_pages(blocks)
            mig = KVMigration(
                req=s.req, n_tokens=s.pos, block_size=self.block_size,
                layer_kv=layer_kv,
                last_logits=np.array(self._last_logits[i]),
                kv_bytes=KVMigration.payload_bytes(layer_kv))
            s.req.prefill_finish_time = now
            self.tracer.mark(s.req.rid, "prefill_finish", now)
            self.migrations += 1
            self.migrated_kv_bytes += mig.kv_bytes
            self.dispatcher.send(self, mig, now)
            self._on_slot_free(i)
            self.slots[i] = _Slot()

    # ---- live migration / evacuation (online rescheduling) -----------------
    def extract_live_slots(self, now: float,
                           slot_ids: Optional[Sequence[int]] = None
                           ) -> List[KVMigration]:
        """Package DECODING slots as live ``KVMigration``s — pages,
        sampling state, AND the tokens already emitted (``out_tokens``) —
        then free them. The destination's ``_place_migrations`` resumes
        the stream mid-flight: same pages, same ``last_logits``, same
        ``out`` prefix, so the token stream is identical to never having
        moved. Mid-prefill slots are not extractable (their cache is
        partial); ``evacuate`` requeues those for a cold re-prefill.

        This is the PLANNED-migration half of the online rescheduler: a
        healthy replica being rebalanced away hands its in-flight work to
        the new layout without draining."""
        ids = range(self.n_slots) if slot_ids is None else slot_ids
        order = sorted((i for i in ids if self.slots[i].decoding),
                       key=lambda i: self.slots[i].seq)
        migs: List[KVMigration] = []
        for i in order:
            s = self.slots[i]
            blocks = [list(tabs[i].blocks) if tabs is not None else None
                      for tabs in self._tables]
            if self._san is not None:
                for si, b in enumerate(blocks):
                    if b is not None:   # pure read: the handoff extraction
                        self._san.slot_access(si, b, s.pos, s.pos,
                                              self.block_size)
            layer_kv = self.pipeline.extract_kv_pages(blocks)
            migs.append(KVMigration(
                req=s.req, n_tokens=s.pos, block_size=self.block_size,
                layer_kv=layer_kv,
                last_logits=np.array(self._last_logits[i]),
                kv_bytes=KVMigration.payload_bytes(layer_kv),
                out_tokens=np.asarray(s.out, np.int32)))
            self.migrations += 1
            self.migrated_kv_bytes += migs[-1].kv_bytes
            if self.tracer.enabled:
                self.tracer.instant("live_move", ts=now,
                                    pid=self.replica_id, rid=s.req.rid,
                                    tokens=s.pos,
                                    bytes=migs[-1].kv_bytes)
            self._on_slot_free(i)
            self.slots[i] = _Slot()
        return migs

    def evacuate(self, now: float) -> List[Request]:
        """Release EVERYTHING in flight and return the orphaned requests:
        queued arrivals, mid-prefill slots, decoding slots, and in-transit
        migrations parked at this replica. Every page is released through
        the normal table path (KVSAN-clean — death must not leak), and the
        requests restart from their prompts wherever the caller
        re-dispatches them; greedy decode regenerates the identical token
        stream, so a replica kill costs latency, never correctness.

        This is the FAILURE half of the online rescheduler (and the
        drain-free teardown for planned removals after
        ``extract_live_slots`` took the movable slots)."""
        orphans: List[Request] = list(self._queue)
        self._queue.clear()
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            orphans.append(s.req)
            self._on_slot_free(i)
            self.slots[i] = _Slot()
        while self._migrations:
            _, _, mig = heapq.heappop(self._migrations)
            orphans.append(mig.req)
        return orphans

    # ---- SlotEngine hooks --------------------------------------------------
    def _fits(self, r: Request) -> bool:
        if len(r.prompt) + r.max_new_tokens > self.max_len - 1:
            return False
        # a request whose full generation cannot fit the pool even alone
        # would preempt itself forever; turn it away instead
        need = blocks_for_tokens(len(r.prompt) + r.max_new_tokens,
                                 self.block_size)
        return need <= self._usable_blocks()

    def _can_admit(self, r: Request, batch: Sequence[Request]) -> bool:
        # prompt + headroom, same footprint capacity() advertises: admitting
        # on bare prompt blocks would prefill a request only to have its
        # first growth block evict it again (insert/preempt thrash)
        pending = sum(self._blocks_needed(q) for q in batch)
        if self._min_pool_free() < pending + self._blocks_needed(r):
            return False
        self._need_sum += self._blocks_needed(r)
        self._need_cnt += 1
        return True

    def _ensure_device_caches(self) -> None:
        if (self.pipeline.paged_caches is None
                or self.pipeline.n_slots != self.n_slots
                or self.pipeline.slot_len != self.max_len
                or self.pipeline.block_size != self.block_size
                or self.pipeline.stage_blocks != self.stage_blocks
                or self.pipeline.kv_dtype != self.kv_dtype
                or self.pipeline.kv_guard_layers != self.kv_guard_layers):
            self.pipeline.init_paged_caches(
                self.n_slots, self.max_len, block_size=self.block_size,
                stage_blocks=self.stage_blocks, kv_dtype=self.kv_dtype,
                kv_guard_layers=self.kv_guard_layers)
            self._account_kv_bytes()

    def _account_kv_bytes(self) -> None:
        """ServeStats counters: bytes the page pools actually occupy
        (payload + scale leaves) and bytes saved vs the model-default
        cache dtype (what kv_dtype=None would have allocated)."""
        base_itemsize = jnp.dtype(M._pdt(self.pipeline.cfg)).itemsize
        resident, baseline = 0, 0
        for caches in self.pipeline.paged_caches:
            for c in caches:
                if "k" not in c or "v" not in c:
                    continue       # recurrent slot state: not paged KV
                for n in ("k", "v"):
                    resident += c[n].size * c[n].dtype.itemsize
                    baseline += c[n].size * base_itemsize
                for n in ("k_scale", "v_scale"):
                    if n in c:
                        resident += c[n].size * c[n].dtype.itemsize
        self.kv_bytes_resident += int(resident)
        self.kv_bytes_saved += int(max(baseline - resident, 0))

    def _stage_alloc(self, si: int, table: BlockTable,
                     n_tokens: int) -> bool:
        """Grow `table` to hold n_tokens, reclaiming cached-prefix blocks
        from stage si's index if the pool proper is dry."""
        pool, ix = self._pools[si], self._prefix[si]
        need = blocks_for_tokens(n_tokens, self.block_size) - table.n_blocks
        if need <= 0:
            return True
        if pool.n_free < need and ix is not None:
            ix.evict(need - pool.n_free)
        before = table.n_blocks
        ok = table.allocate_tokens(n_tokens)
        if table.n_blocks != before:
            self._bt_cache = None
        return ok

    def _prefill_insert(self, toks, lens, slot_ids):
        self._ensure_device_caches()
        self._bt_cache = None
        m = len(slot_ids)
        self.prefill_tokens += int(np.sum(lens[:m]))
        self._iter_prefill_tokens += int(np.sum(lens[:m]))
        stage_dest = []
        for si, tabs in enumerate(self._tables):
            if tabs is None:
                stage_dest.append(
                    np.zeros(m * self.max_blocks, np.int32))
                continue
            dest = np.zeros((m, self.max_blocks), np.int32)
            for row, slot in enumerate(slot_ids):
                t = tabs[slot]
                assert not t.blocks, "slot freed without releasing blocks"
                ok = self._stage_alloc(si, t, int(lens[row]))
                assert ok, "admission admitted more blocks than the pool has"
                if self._san is not None:
                    self._san.slot_access(si, t.blocks, int(lens[row]), 0,
                                          self.block_size)
                dest[row] = t.as_array(self.max_blocks)
            stage_dest.append(dest.reshape(-1))
        return self.pipeline.insert_slots_paged(toks, lens, slot_ids,
                                                stage_dest)

    # ---- incremental insert: prefix match + deferred (chunked) prefill ----
    def _insert_batch(self, reqs: Sequence[Request],
                      slot_ids: Sequence[int]) -> None:
        if not self._incremental:
            return super()._insert_batch(reqs, slot_ids)
        self._ensure_device_caches()
        for r, slot in zip(reqs, slot_ids):
            self._setup_slot(r, slot)

    def _setup_slot(self, r: Request, slot: int) -> None:
        """Admission in incremental mode: queue the whole prompt as pending
        prefill. The prefix lookup runs LAZILY at the slot's first prefill
        step (_match_slot) rather than here: _prefill_step visits slots
        oldest-first, so a later arrival admitted in the same batch still
        sees the blocks an earlier one registered this very iteration.
        No model work happens here."""
        hashes = chunk_hashes(r.prompt, self.block_size) \
            if self.prefix_caching else []
        self.slots[slot] = _Slot(req=r, pos=0,
                                 remaining=r.max_new_tokens, out=[],
                                 seq=self._admit_seq,
                                 pending=np.asarray(r.prompt, np.int32),
                                 hashes=hashes,
                                 matched=not self.prefix_caching)
        self._admit_seq += 1

    def _match_slot(self, i: int) -> None:
        """First-touch prefix lookup for slot i: alias the longest
        device-indexed prefix (incref per stage), then EXTEND the match
        down the memory hierarchy — host-tier pages swap back into fresh
        device blocks, pages resident only on peer replicas migrate over
        the KV link — and drop the whole matched prefix from the pending
        prefill."""
        s = self.slots[i]
        s.matched = True
        if not s.hashes:
            return
        self.prefix_lookups += 1
        L = min(ix.match_len(s.hashes)
                for ix in self._prefix if ix is not None)
        if L:
            # alias the hit prefix in EVERY stage (symmetric indexes:
            # registered/evicted together, so L agrees up to eviction
            # races — min() above settles those), incref-ing BEFORE any
            # tier promotion so a promotion's eviction can never take
            # what this very match already claimed
            for tabs, ix in zip(self._tables, self._prefix):
                if tabs is None:
                    continue
                t = tabs[i]
                assert not t.blocks, "slot freed without releasing"
                t.adopt(ix.acquire(s.hashes[:L]))
        Lx = L
        if self._tiered:
            while Lx < len(s.hashes) \
                    and self._materialize_hash(i, s.hashes[Lx]):
                Lx += 1
        if not Lx:
            return
        # always leave >= 1 cold token: the final logits must come from a
        # real forward pass (a fully cached prompt re-runs its last token,
        # copy-on-write duplicating the shared tail block)
        cold = min(Lx * self.block_size, len(s.req.prompt) - 1)
        s.pos = cold
        s.pending = s.pending[cold:]
        self.prefix_hits += 1
        self.prefix_hit_tokens += cold
        self._bt_cache = None

    def _prepare_chunk(self, i: int, target_tokens: int) -> bool:
        """Make [slot i's tables] able to hold target_tokens AND the next
        write position exclusively owned (copy-on-write). False when some
        pool is dry even after eviction — caller preempts and retries."""
        pos = self.slots[i].pos
        for si, tabs in enumerate(self._tables):
            if tabs is None:
                continue
            t = tabs[i]
            if not self._stage_alloc(si, t, target_tokens):
                return False
            bi = pos // self.block_size
            if bi < t.n_blocks:
                pool, ix = self._pools[si], self._prefix[si]
                if pool.n_free < 1 and ix is not None \
                        and pool.ref(t.blocks[bi]) > 1:
                    ix.evict(1)
                cow = t.writable(bi)
                if cow is False:
                    return False
                if cow is not None:
                    src, dst = cow
                    self.pipeline.copy_pages(si, [src], [dst])
                    if self._san is not None:
                        self._san.on_copy(si, src, dst)
                    self.cow_copies += 1
                    self._bt_cache = None
        return True

    def _prefill_step(self, now: float) -> None:
        """Run ONE prefill chunk for every mid-prefill slot, oldest first —
        interleaved with the decode so a long cold prompt shares the
        iteration budget instead of monopolizing it. Same-iteration chunks
        coalesce into joint context dispatches; the batch flushes whenever
        a slot COMPLETES its prompt (it registers its blocks on flush, so
        a later same-iteration arrival with the same prefix still matches
        instead of re-prefilling — dedup beats batching there)."""
        order = sorted((i for i, s in enumerate(self.slots)
                        if not s.free and s.pending is not None),
                       key=lambda i: self.slots[i].seq)
        group: List = []               # (slot, chunk) awaiting one dispatch
        for i in order:
            s = self.slots[i]
            if s.free or s.pending is None:
                continue               # preempted by an earlier slot's turn
            if not s.matched:
                # match AFTER flushing so this lookup sees every block the
                # batch's completed prompts just registered
                self._dispatch_chunks(group)
                self._match_slot(i)
            chunk = len(s.pending) if self.prefill_chunk <= 0 \
                else min(self.prefill_chunk, len(s.pending))
            while not self.slots[i].free \
                    and not self._prepare_chunk(i, s.pos + chunk):
                active = [j for j, sl in enumerate(self.slots)
                          if not sl.free]
                self._preempt(max(active,
                                  key=lambda j: self.slots[j].seq))
            if self.slots[i].free:
                continue               # evicted itself; requeued up front
            group.append((i, chunk))
            if self.prefix_caching and chunk == len(s.pending):
                self._dispatch_chunks(group)
        self._dispatch_chunks(group)

    def _dispatch_chunks(self, group: List) -> None:
        """Joint (m, C) right-padded context-prefill call for the queued
        (slot, chunk) pairs: slot i's next `chunk` pending tokens run at
        absolute positions [pos, pos+chunk). Width buckets to multiples of
        16 so mixed chunk lengths compile O(log) shapes. Clears `group`."""
        pairs = [(i, c) for i, c in group
                 if not self.slots[i].free]   # a later prepare may preempt
        group.clear()
        if not pairs:
            return
        m = len(pairs)
        C = min(-(-max(c for _, c in pairs) // 16) * 16, self.max_len - 1)
        toks = np.full((m, C), self.pad_id, np.int32)
        lens = np.zeros(m, np.int32)
        starts = np.zeros(m, np.int32)
        for row, (i, c) in enumerate(pairs):
            s = self.slots[i]
            toks[row, :c] = s.pending[:c]
            lens[row] = c
            starts[row] = s.pos
        if self._san is not None:
            for si, tabs in enumerate(self._tables):
                if tabs is None:
                    continue
                for row, (i, c) in enumerate(pairs):
                    self._san.slot_access(
                        si, tabs[i].blocks, int(starts[row]) + c,
                        int(starts[row]), self.block_size)
        tables = [np.zeros((m, self.max_blocks), np.int32) if tabs is None
                  else np.stack([tabs[i].as_array(self.max_blocks)
                                 for i, _ in pairs])
                  for tabs in self._tables]
        logits = np.asarray(self.pipeline.context_slots_paged(
            toks, lens, starts, tables))
        if self.tracer.enabled:
            ntok = int(lens.sum())
            self.tracer.complete(
                "prefill",
                self.virtual_step_cost * self.prefill_token_cost * ntok,
                pid=self.replica_id, tokens=ntok, slots=m)
        for row, (i, c) in enumerate(pairs):
            s = self.slots[i]
            s.pos += c
            s.pending = s.pending[c:]
            self.prefill_tokens += c
            self._iter_prefill_tokens += c
            if len(s.pending) == 0:    # prompt fully cached: decode next
                s.pending = None
                self._last_logits[i] = logits[row]
                self._register_prefix(i, s)
                self._bt_cache = None
                self.tracer.mark(s.req.rid, "prefill_finish",
                                 self.tracer.now())

    def _register_prefix(self, i: int, s: _Slot) -> None:
        """Index the prompt's full blocks so later prompts can alias them
        (the index takes its own reference; entries already present keep
        their canonical block). Registration supersedes any host-tier copy
        (one-tier invariant) and publishes device residency to the cluster
        directory."""
        if not self.prefix_caching or not s.hashes:
            return
        for tabs, ix, host in zip(self._tables, self._prefix, self._host):
            if tabs is None or ix is None:
                continue
            ix.register(s.hashes, tabs[i].blocks[:len(s.hashes)])
            if host is not None:
                for h in s.hashes:
                    host.discard(h)
        if self.cluster_dir is not None:
            for h in s.hashes:
                self.cluster_dir.publish(h, self.replica_id, "device")

    # ---- tiered pages: host spill pool + cluster prefix directory ---------
    @property
    def _tiered(self) -> bool:
        return (any(hp is not None for hp in self._host)
                or self.cluster_dir is not None)

    def _make_spill(self, si: int):
        """Demotion closure for stage si's PrefixIndex: an evicted prefix
        block's page payload moves device -> host instead of vanishing."""
        host = self._host[si]

        def spill(h: int, bid: int) -> None:
            if self.pipeline.paged_caches is None:
                return             # nothing ever materialized on device
            if self._san is not None:
                self._san.on_spill(si, bid)
            host.put(h, self.pipeline.extract_stage_pages(si, [bid]))
            self.host_demotions += 1
            self._iter_swap_blocks += 1
            if self.tracer.enabled:
                self.tracer.complete(
                    "host_spill",
                    self.virtual_step_cost * self.host_swap_cost,
                    pid=self.replica_id, tid=si)
            if si == self._rep_stage and self.cluster_dir is not None:
                self.cluster_dir.publish(h, self.replica_id, "host")
        return spill

    def _make_host_drop(self, si: int):
        """LRU-bound closure for stage si's HostPagePool: the page has now
        left this replica entirely (bottom of the hierarchy)."""
        def dropped(h: int) -> None:
            self.host_evictions += 1
            if si == self._rep_stage and self.cluster_dir is not None:
                self.cluster_dir.unpublish(h, self.replica_id)
        return dropped

    def attach_cluster(self, directory, peers: Dict[int, object],
                       link: Optional[KVLink]) -> None:
        """Join a cluster prefix directory (cluster_kv.wire_cluster_prefix):
        publish this replica's residency and fetch hot prefixes from
        `peers` (replica_id -> engine) over `link`."""
        assert self.prefix_caching, \
            "cluster prefix sharing needs prefix_caching=True"
        self.cluster_dir = directory
        self.cluster_link = link if link is not None else KVLink()
        self._cluster_peers = {rid: w for rid, w in peers.items()
                               if rid != self.replica_id}
        # without a host tier, an evicted prefix block leaves the replica
        # entirely — retract the directory claim at eviction time so the
        # published residency never outlives the page (peers would only
        # have wasted a fetch attempt on the stale entry, but KVSAN's
        # directory audit rightly calls the dangling claim a violation)
        ix = (self._prefix[self._rep_stage]
              if self._rep_stage is not None else None)
        if ix is not None and ix.spill is None:
            def _unpublish_on_evict(h: int, bid: int) -> None:
                self.cluster_dir.unpublish(h, self.replica_id)
            ix.spill = _unpublish_on_evict

    def export_prefix_block(self, h: int):
        """Package chain hash `h`'s page payload for a peer replica —
        global layer order, the ``KVMigration`` wire format — sourcing
        each stage from its device index or host tier (a COPY ships;
        local residency is untouched). None when some stage no longer
        holds the page (the caller unpublishes the stale directory
        entry and prefills cold)."""
        if self.pipeline.paged_caches is None:
            return None
        layer_kv: List[dict] = []
        for si, (pool, ix, host) in enumerate(
                zip(self._pools, self._prefix, self._host)):
            if pool is None or ix is None:
                return None        # non-attention stage: nothing to export
            bid = ix.lookup(h)
            if bid is not None:
                if self._san is not None:   # peer export reads the page
                    self._san.on_spill(si, bid)
                layer_kv.extend(self.pipeline.extract_stage_pages(si, [bid]))
                continue
            payload = host.peek(h) if host is not None else None
            if payload is None:
                return None
            layer_kv.extend(payload)
        return layer_kv

    def _materialize_hash(self, i: int, h: int) -> bool:
        """Make chain hash `h` device-resident, registered, and aliased
        into slot i's tables in EVERY attention stage. Per stage the
        source is the device index (plain alias), this replica's host
        tier (swap-in: the payload scatters into a fresh block), or a
        peer replica named by the cluster directory (hot-prefix migration
        in the KVMigration wire format, charged at KVLink delay on the
        serving clock). False when some stage holds the page nowhere
        reachable or a pool stays dry even after eviction — the caller
        stops extending and prefills the remainder cold."""
        plan: List = []            # (si, "device" | "host" | "fetch")
        need_fetch = False
        for si, (pool, ix, host) in enumerate(
                zip(self._pools, self._prefix, self._host)):
            if pool is None or ix is None:
                continue
            if ix.lookup(h) is not None:
                plan.append((si, "device"))
            elif host is not None and h in host:
                plan.append((si, "host"))
            else:
                plan.append((si, "fetch"))
                need_fetch = True
        if not plan:
            return False
        layer_kv, src_rid = None, None
        if need_fetch:
            if self.cluster_dir is None:
                return False
            for rid, _tier in self.cluster_dir.holders(
                    h, exclude=self.replica_id):
                peer = self._cluster_peers.get(rid)
                if peer is None:
                    continue
                layer_kv = peer.export_prefix_block(h)
                if layer_kv is not None:
                    src_rid = rid
                    break
                self.cluster_dir.unpublish(h, rid)   # stale entry
            if layer_kv is None:
                return False
        # pop host payloads BEFORE allocating: allocation may evict-demote
        # other blocks into the host pool, and the LRU drop absorbing them
        # must never take the very payload being promoted
        payloads = {}
        for si, kind in plan:
            if kind == "host":
                payloads[si] = self._host[si].get(h)
                assert payloads[si] is not None, "planned host page vanished"
        alloc: Dict[int, int] = {}
        for si, kind in plan:
            if kind == "device":
                continue
            pool, ix = self._pools[si], self._prefix[si]
            if pool.n_free < 1:
                ix.evict(1)
            got = pool.alloc(1)
            if got is None:        # dry even after eviction: roll back
                for sj, bid in alloc.items():
                    self._pools[sj].free(bid)
                for sj, payload in payloads.items():
                    self._host[sj].restore(h, payload)
                return False
            alloc[si] = got[0]
        # land the payloads
        promoted = False
        dest: List = [None] * len(self._tables)
        for si, kind in plan:
            if kind == "host":
                self.pipeline.scatter_stage_pages(si, [alloc[si]],
                                                  payloads[si])
                if self._san is not None:
                    self._san.note_write(si, [alloc[si]])
                promoted = True
                self.host_promotions += 1
                self._iter_swap_blocks += 1
                if self.tracer.enabled:
                    self.tracer.complete(
                        "host_promote",
                        self.virtual_step_cost * self.host_swap_cost,
                        pid=self.replica_id, tid=si)
            elif kind == "fetch":
                dest[si] = [alloc[si]]
        if need_fetch:
            # only the locally-missing stages' layer slices cross the link
            self.pipeline.scatter_kv_pages(dest, layer_kv)
            if self._san is not None:
                for sj, d in enumerate(dest):
                    if d is not None:
                        self._san.note_write(sj, d)
            fetch_bytes, li = 0, 0
            for si, st in enumerate(self.pipeline.stages):
                n_layers = st.hi - st.lo
                if dest[si] is not None:
                    fetch_bytes += KVMigration.payload_bytes(
                        layer_kv[li:li + n_layers])
                li += n_layers
            self.prefix_fetches += 1
            self.prefix_fetched_bytes += fetch_bytes
            fetch_cost = self.cluster_link.delay(
                fetch_bytes, src_rid, self.replica_id)
            self._iter_fetch_cost += fetch_cost
            if self.tracer.enabled:
                self.tracer.complete("prefix_fetch", fetch_cost,
                                     pid=self.replica_id,
                                     src=src_rid, bytes=fetch_bytes)
        if promoted:
            self.host_hit_tokens += self.block_size
        # register + alias: the index takes its own reference, the table
        # takes over the allocation's — refcount 2, exactly the prefill
        # registration shape, so the new block is immune to eviction while
        # deeper hashes of this very chain materialize
        for si, kind in plan:
            ix, t = self._prefix[si], self._tables[si][i]
            if kind == "device":
                t.adopt(ix.acquire([h]))
            else:
                ix.register([h], [alloc[si]])
                t.adopt([alloc[si]])
        if self.cluster_dir is not None:
            self.cluster_dir.publish(h, self.replica_id, "device")
        return True

    def _ensure_blocks(self, i: int) -> bool:
        # decode writes at pos: grow to hold it AND copy-on-write if the
        # target block is still shared (defensive — full-block-only
        # sharing means decode normally lands in exclusive blocks)
        return self._prepare_chunk(i, self.slots[i].pos + 1)

    def _before_decode(self) -> None:
        """Allocate-on-decode growth; preempt-by-recompute when a pool runs
        dry. Oldest slots grow first and the YOUNGEST active slot is
        evicted — possibly the requester itself — so the head of the line
        always makes progress (no livelock: a request that cannot fit even
        alone was rejected by _fits)."""
        order = sorted((i for i, s in enumerate(self.slots)
                        if s.decoding), key=lambda i: self.slots[i].seq)
        for i in order:
            while self.slots[i].decoding and not self._ensure_blocks(i):
                active = [j for j, sl in enumerate(self.slots)
                          if not sl.free]
                self._preempt(max(active, key=lambda j: self.slots[j].seq))

    def _preempt(self, i: int) -> None:
        s = self.slots[i]
        for tabs in self._tables:
            if tabs is not None:
                tabs[i].release()
        self._bt_cache = None
        if self._proposer is not None:
            self._proposer.release(i)
        # recompute: the request restarts from its prompt (greedy decode
        # regenerates the same prefix), at the FRONT of the queue
        self._queue.appendleft(s.req)
        self.slots[i] = _Slot()
        self.preemptions += 1
        if self.tracer.enabled:
            # the recompute itself shows up as this request's next
            # prefill span; the eviction is the instant
            self.tracer.instant("preempt", pid=self.replica_id,
                                rid=s.req.rid, slot=i, pos=s.pos)

    def _on_slot_free(self, i: int) -> None:
        for tabs in self._tables:
            if tabs is not None:
                tabs[i].release()
        self._bt_cache = None
        if self._proposer is not None:
            self._proposer.release(i)

    # ---- speculative decoding (draft -> multi-token verify -> accept) ----
    def _spec_iteration(self, now: float):
        """One target step under speculative decoding: PROPOSE a candidate
        chunk per decoding slot (the bonus token — the argmax the plain
        decode would feed next — plus up to ``spec.k`` drafts), ENSURE
        blocks/COW for the whole chunk (a dry pool preempts the youngest
        active slot, exactly like plain decode growth), VERIFY every
        slot's chunk in one multi-token pipeline step, then ACCEPT the
        longest draft prefix matching the target's argmax chain and ROLL
        BACK the speculative pages past the committed length. Greedy
        acceptance keeps the committed stream token-identical to plain
        greedy decode; the win is committing up to k + 1 tokens per
        target step."""
        k = self.spec.k
        items = []
        for i, s in enumerate(self.slots):
            if not s.decoding:
                continue
            bonus = int(self._last_logits[i].argmax())
            # the chunk must fit the request's remaining budget AND the
            # slot ceiling (writes stop at max_len - 2, like decode)
            cap = max(min(k, s.remaining - 1, self.max_len - 2 - s.pos), 0)
            hist = np.concatenate([
                np.asarray(s.req.prompt, np.int32),
                np.asarray(s.out, np.int32),
                np.asarray([bonus], np.int32)])
            items.append((i, bonus, hist, cap))
        props = self._proposer.propose(
            [(i, hist, cap) for i, _, hist, cap in items])
        n_prop = sum(len(p) for p in props.values())
        self._iter_spec_proposed += n_prop
        if self.tracer.enabled and n_prop:
            self.tracer.complete(
                "spec_propose",
                self.virtual_step_cost * self.spec.draft_token_cost
                * n_prop,
                ts=now, pid=self.replica_id, tokens=n_prop)
        # block growth + copy-on-write for the whole chunk, oldest first
        plan = {}
        empty = np.zeros(0, np.int32)
        for i, bonus, hist, cap in sorted(
                items, key=lambda it: self.slots[it[0]].seq):
            if not self.slots[i].decoding:
                continue           # preempted by an earlier slot's turn
            drafts = np.asarray(props.get(i, empty), np.int32)[:cap]
            while self.slots[i].decoding and not self._prepare_chunk(
                    i, self.slots[i].pos + 1 + len(drafts)):
                active = [j for j, sl in enumerate(self.slots)
                          if not sl.free]
                self._preempt(max(active, key=lambda j: self.slots[j].seq))
            if self.slots[i].decoding:
                plan[i] = (bonus, drafts)
        if not plan:
            return []              # everyone preempted themselves away
        # joint verification dispatch: FIXED chunk width k + 1 (one
        # compile), per-slot real counts; absent slots are dead rows with
        # null tables, like free slots in the joint decode
        T = k + 1
        toks = np.zeros((self.n_slots, T), np.int32)
        qlen = np.zeros((self.n_slots,), np.int32)
        starts = np.zeros((self.n_slots,), np.int32)
        for i, (bonus, drafts) in plan.items():
            toks[i, 0] = bonus
            toks[i, 1:1 + len(drafts)] = drafts
            qlen[i] = 1 + len(drafts)
            starts[i] = self.slots[i].pos
        tables = [np.zeros((self.n_slots, self.max_blocks), np.int32)
                  if tabs is None else
                  np.stack([t.as_array(self.max_blocks) if j in plan
                            else np.zeros(self.max_blocks, np.int32)
                            for j, t in enumerate(tabs)])
                  for tabs in self._tables]
        if self._san is not None:
            for si, tabs in enumerate(self._tables):
                if tabs is None:
                    continue
                for i in plan:
                    self._san.slot_access(
                        si, tabs[i].blocks, int(starts[i]) + int(qlen[i]),
                        int(starts[i]), self.block_size)
        logits = np.asarray(self.pipeline.verify_slots_paged(
            toks, qlen, starts, tables))
        if self.tracer.enabled:
            # the multi-token verification step is the iteration's target
            # pass: flat iteration cost, like a plain decode step
            self.tracer.complete("spec_verify", self.virtual_step_cost,
                                 ts=now, pid=self.replica_id,
                                 slots=len(plan))
        done = []
        for i, (bonus, drafts) in plan.items():
            s = self.slots[i]
            commit, a = greedy_accept(logits[i], bonus, drafts)
            self.spec_steps += 1
            self.spec_proposed += len(drafts)
            self.spec_accepted += a
            self.spec_tokens += len(commit)
            # logits[a] is the distribution after the last committed
            # token — its argmax is the next step's bonus token
            self._last_logits[i] = logits[i, a]
            if not s.out and s.req is not None:
                # first-wins across preempt-recompute, like plain decode
                if s.req.first_token_time is None:
                    s.req.first_token_time = now
                self.tracer.mark(s.req.rid, "first_token", now)
            s.out.extend(commit)
            s.pos += len(commit)
            s.remaining -= len(commit)
            # speculative-page rollback: blocks wholly past the committed
            # length return to the pool (prefix-index aliases survive —
            # truncate drops one reference like any release)
            freed = 0
            for tabs in self._tables:
                if tabs is not None:
                    freed += tabs[i].truncate(s.pos)
            if freed:
                self._bt_cache = None
                if self.tracer.enabled:
                    self.tracer.instant("spec_rollback", ts=now,
                                        pid=self.replica_id, slot=i,
                                        blocks=freed)
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                done.append((s.req, s.out))
                self._on_slot_free(i)
                self.slots[i] = _Slot()
            else:
                self._proposer.commit(i, a)
        return done

    def _step(self, now: float):
        if self._incremental:
            self._prefill_step(now)
        if self.role == "prefill":
            self._migrate_ready(now)   # hand off instead of decoding
            return []
        if any(s.decoding for s in self.slots):
            if self.spec is not None:
                return self._spec_iteration(now)
            return self._decode_iteration(now)
        return []                  # every occupied slot is still prefilling

    def run_iteration(self, now: float):
        self._iter_prefill_tokens = 0
        self._iter_spec_proposed = 0
        self._iter_swap_blocks = 0
        self._iter_fetch_cost = 0.0
        # land arrived migrations BEFORE the base iteration so their slots
        # join this very decode step (mirrors colocated serving, where a
        # prefill finishing in iteration i decodes its first token in i)
        mig_comps = self._place_migrations(now) if self._migrations else []
        comps, cost = super().run_iteration(now)
        # virtual accounting: charge prefilled tokens a fraction of an
        # iteration so chunking/prefix hits show up in simulated latency
        if self._iter_prefill_tokens and self.prefill_token_cost:
            cost += (self.virtual_step_cost * self.prefill_token_cost
                     * self._iter_prefill_tokens)
        # ... and draft proposals their configured fraction, so the
        # acceptance-aware cost model's draft overhead is measurable
        if self._iter_spec_proposed and self.spec is not None \
                and self.spec.draft_token_cost:
            cost += (self.virtual_step_cost * self.spec.draft_token_cost
                     * self._iter_spec_proposed)
        # ... and every block crossing the device<->host boundary its swap
        # cost, plus cluster prefix fetches their modeled link delay — the
        # tiers are only a win when the swap is cheaper than the recompute
        # it replaces, and the clock must be able to say so
        if self._iter_swap_blocks and self.host_swap_cost:
            cost += (self.virtual_step_cost * self.host_swap_cost
                     * self._iter_swap_blocks)
        if self._iter_fetch_cost:
            cost += self._iter_fetch_cost
        if self._san is not None:
            self._kvsan_audit()
        return mig_comps + comps, cost

    def _kvsan_audit(self) -> None:
        """Iteration-boundary KVSAN audit: every pool reference must be
        explained by a slot's BlockTable or a PrefixIndex entry
        (unexplained references count as leaks -> kvsan_leaks; a
        reference a table expects but the pool lost raises), the host
        shadow must match the actual host tier, and every directory
        entry this replica published must point at a page it still
        holds."""
        san = self._san
        for si, pool in enumerate(self._pools):
            if pool is None:
                continue
            expected: Dict[int, int] = {}
            for t in self._tables[si]:
                for b in t.blocks:
                    expected[b] = expected.get(b, 0) + 1
            ix = self._prefix[si]
            if ix is not None:
                for bid in ix.indexed_blocks():
                    expected[bid] = expected.get(bid, 0) + 1
            self.kvsan_leaks += san.audit_pool(si, pool, expected)
            host = self._host[si]
            if host is not None:
                san.audit_host(si, host)
        if self.cluster_dir is not None and self._rep_stage is not None:
            ix = self._prefix[self._rep_stage]
            host = self._host[self._rep_stage]
            for h, tier in self.cluster_dir.entries_for(self.replica_id):
                if tier == "device" and (ix is None
                                         or ix.lookup(h) is None):
                    san.violate(
                        f"kvsan replica {self.replica_id}: directory "
                        f"says device for hash {h} but no block is "
                        "resident")
                elif tier == "host" and (host is None or h not in host):
                    san.violate(
                        f"kvsan replica {self.replica_id}: directory "
                        f"says host for hash {h} but the host tier "
                        "lacks it")

    def _decode_all(self, toks, pos):
        if self._san is not None:
            for si, tabs in enumerate(self._tables):
                if tabs is None:
                    continue
                for j, s in enumerate(self.slots):
                    if s.decoding:
                        self._san.slot_access(
                            si, tabs[j].blocks, int(pos[j]) + 1,
                            int(pos[j]), self.block_size)
        if self._bt_cache is None:
            # rows of slots that are NOT decoding (free, or mid-prefill)
            # present an all-null table so their joint-iteration garbage
            # write lands in the trash page, never in allocated blocks
            self._bt_cache = [
                np.zeros((self.n_slots, self.max_blocks), np.int32)
                if tabs is None else
                np.stack([t.as_array(self.max_blocks)
                          if self.slots[j].decoding else
                          np.zeros(self.max_blocks, np.int32)
                          for j, t in enumerate(tabs)])
                for tabs in self._tables]
        return self.pipeline.decode_slots_paged(toks, pos, self._bt_cache)
