"""Continuous (iteration-level) batching — the paper's acknowledged
limitation (Appendix D), implemented here as a beyond-paper extension.

A replica owns a fixed pool of decode SLOTS backed by pre-allocated caches.
Requests admitted by the serve loop are buffered until the next iteration
boundary, then prefilled JOINTLY (one right-padded batch with per-row real
lengths) and their cache rows scattered into free slots; every iteration
decodes all slots jointly with PER-SLOT positions; finished slots free
immediately. Right padding keeps each row's token positions identical to
isolated generation and attention/MoE/SSM state is row-independent, so a
request's outputs are bit-identical to isolated generation (tested).

Two executors share the slot engine:

  * ``ContinuousBatcher``  — the monolithic single-process model apply
    (one cache pool for the whole stack);
  * ``PipelineBatcher``    — an ``AsymmetricPipeline`` replica (per-STAGE
    cache pools, so a multi-stage heterogeneous replica serves at iteration
    granularity end to end).

Works for full-KV and recurrent-state architectures; SWA ring caches
require uniform positions and fall back to static batching (noted).

Both implement the replica port of ``serving.loop`` — scheduling, clocking
and accounting live there, not here.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.loop import (ServeStats, VirtualClock, WallClock,
                                run_serve_loop)
from repro.serving.request import Request


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0               # next write position
    remaining: int = 0
    out: Optional[list] = None

    @property
    def free(self) -> bool:
        return self.req is None


class SlotEngine:
    """Slot bookkeeping + the joint insert/decode iteration, shared by the
    monolithic and pipeline executors. Subclasses provide:

      _prefill_insert(toks (b,P), lens (b,), slot_ids) -> logits (m, V)
          where m = len(slot_ids) <= b; rows beyond m are compile-shape
          padding to be dropped before the cache scatter
      _decode_all(toks (n_slots,), pos (n_slots,))     -> logits (n_slots, V)
    """

    def __init__(self, *, n_slots: int, max_len: int, vocab_size: int,
                 pad_id: int = 0, virtual_step_cost: float = 1.0):
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self.virtual_step_cost = virtual_step_cost
        self.slots = [_Slot() for _ in range(n_slots)]
        self._queue: List[Request] = []
        self._last_logits = np.zeros((n_slots, vocab_size), np.float32)

    # ---- slot state ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    @property
    def active(self) -> bool:
        return any(not s.free for s in self.slots)

    # ---- replica port (serving.loop) -------------------------------------
    def capacity(self, now: float) -> int:
        return max(len(self.free_slots()) - len(self._queue), 0)

    def load(self, now: float) -> float:
        return (self.n_slots - len(self.free_slots())) + len(self._queue)

    def admit(self, reqs: Sequence[Request], now: float) -> None:
        self._queue.extend(reqs)

    def busy(self, now: float) -> bool:
        return bool(self._queue) or self.active

    def inflight(self) -> int:
        return len(self._queue) + (self.n_slots - len(self.free_slots()))

    def next_event(self, now: float):
        return None                # compute worker: work runs when busy

    def run_iteration(self, now: float):
        """Insert buffered admissions, then one joint decode iteration."""
        comps = []
        free = self.free_slots()
        if self._queue and free:
            batch = []
            while self._queue and len(batch) < len(free):
                r = self._queue.pop(0)
                # a request must fit prompt + all its decode steps in one
                # slot; reject it alone (empty output) instead of crashing
                # the serve loop and losing every in-flight request
                if len(r.prompt) + r.max_new_tokens > self.max_len - 1:
                    warnings.warn(
                        f"request {r.rid}: prompt {len(r.prompt)} + "
                        f"max_new {r.max_new_tokens} exceeds slot length "
                        f"{self.max_len}; rejected with empty output")
                    comps.append((r, np.zeros(0, np.int32), None))
                    continue
                batch.append(r)
            if batch:
                self._insert_batch(batch, free[:len(batch)])
        # nothing active (e.g. a rejection-only cycle): no decode to run —
        # and possibly no caches allocated yet to run it on
        done = self._decode_iteration() if self.active else []
        comps.extend((req, np.asarray(out, np.int32), None)
                     for req, out in done)
        return comps, self.virtual_step_cost

    # ---- engine internals ------------------------------------------------
    def _insert_batch(self, reqs: Sequence[Request],
                      slot_ids: Sequence[int]) -> None:
        m = len(reqs)
        lens = np.asarray([len(r.prompt) for r in reqs], np.int32)
        assert int(lens.max()) < self.max_len, "prompt exceeds slot length"
        # bucket BOTH jit shape axes — padded width to multiples of 16,
        # insert count to the next power of two (capped at n_slots) — so a
        # bursty serve window compiles O(log) prefill shapes instead of one
        # per distinct (m, P) pair. Pad rows (and right pads) are masked in
        # the model and dropped by _prefill_insert before the scatter.
        P = min(-(-int(lens.max()) // 16) * 16, self.max_len - 1)
        m_pad = min(1 << (m - 1).bit_length(), self.n_slots)
        toks = np.full((m_pad, P), self.pad_id, np.int32)
        plens = np.ones((m_pad,), np.int32)
        plens[:m] = lens
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt                   # right pad
        logits = self._prefill_insert(toks, plens, list(slot_ids))
        for i, (r, slot) in enumerate(zip(reqs, slot_ids)):
            self._last_logits[slot] = np.asarray(logits[i])
            self.slots[slot] = _Slot(req=r, pos=int(lens[i]),
                                     remaining=r.max_new_tokens, out=[])

    def _decode_iteration(self):
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if not s.free:
                toks[i] = int(self._last_logits[i].argmax())
                pos[i] = s.pos
        logits = self._decode_all(toks, pos)
        done = []
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            s.out.append(int(toks[i]))
            s.pos += 1
            s.remaining -= 1
            self._last_logits[i] = logits[i]
            if s.remaining <= 0 or s.pos >= self.max_len - 1:
                done.append((s.req, s.out))
                self.slots[i] = _Slot()
        return done

    def _prefill_insert(self, toks, lens, slot_ids):
        raise NotImplementedError

    def _decode_all(self, toks, pos):
        raise NotImplementedError

    # ---- single-replica convenience (shared loop underneath) --------------
    def serve(self, requests: Sequence[Request], *, deadline: float,
              realtime: bool = False) -> ServeStats:
        """Replays a workload on this replica alone. realtime=False uses the
        virtual clock: deterministic latencies in iteration units."""
        clock = WallClock() if realtime else VirtualClock()
        return run_serve_loop([self], requests, deadline=deadline,
                              clock=clock)

    # seed-API shims (tests, notebooks) ------------------------------------
    def insert(self, req: Request) -> int:
        """Immediate single insert; returns the slot index."""
        free = self.free_slots()
        assert free, "no free slot"
        self._insert_batch([req], free[:1])
        return free[0]

    def step(self) -> Dict[int, List[int]]:
        """One joint decode iteration. Returns {rid: finished tokens}."""
        return {req.rid: out for req, out in self._decode_iteration()}


class ContinuousBatcher(SlotEngine):
    """Slot-based continuous batching on the monolithic model apply (single
    jit over the full stack; the asymmetric-pipeline variant is
    ``PipelineBatcher``)."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 256, pad_id: int = 0, key=None,
                 virtual_step_cost: float = 1.0):
        from repro.serving.pipeline import slot_mode_supported
        assert slot_mode_supported(cfg), \
            "slot mode needs uniform text decode; use static batching"
        super().__init__(n_slots=n_slots, max_len=max_len,
                         vocab_size=cfg.vocab_size, pad_id=pad_id,
                         virtual_step_cost=virtual_step_cost)
        self.cfg = cfg
        self.params = params
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        self._prefill = jax.jit(
            lambda p, toks, lens, c: M.prefill(cfg, p, {"tokens": toks}, c,
                                               lens=lens))

    def _prefill_insert(self, toks, lens, slot_ids):
        m = len(slot_ids)          # toks may carry compile-padding rows > m
        scratch = M.init_cache(self.cfg, toks.shape[0], self.max_len)
        logits, scratch = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(lens), scratch)
        # monolithic cache leaves are period-stacked: batch axis is 1
        rows = jax.tree.map(lambda l: l[:, :m], scratch)
        self.cache = M.scatter_cache_rows(self.cache, rows, slot_ids,
                                          batch_axis=1)
        return np.asarray(logits)[:m]

    def _decode_all(self, toks, pos):
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(pos))
        return np.asarray(logits)


class PipelineBatcher(SlotEngine):
    """Slot-based continuous batching over an ``AsymmetricPipeline``
    replica: per-stage cache pools, iteration-level joint decode with
    per-slot positions, joint right-padded insert prefill."""

    def __init__(self, pipeline, *, n_slots: int = 8, max_len: int = 256,
                 pad_id: int = 0, virtual_step_cost: float = 1.0):
        from repro.serving.pipeline import slot_mode_supported
        assert slot_mode_supported(pipeline.cfg), \
            "slot mode needs uniform text decode; use StaticBatcher"
        super().__init__(n_slots=n_slots, max_len=max_len,
                         vocab_size=pipeline.cfg.vocab_size, pad_id=pad_id,
                         virtual_step_cost=virtual_step_cost)
        self.pipeline = pipeline

    def _prefill_insert(self, toks, lens, slot_ids):
        # pools allocate lazily so generate()-only engines never pay for them
        if (self.pipeline.slot_caches is None
                or self.pipeline.n_slots != self.n_slots
                or self.pipeline.slot_len != self.max_len):
            self.pipeline.init_slot_caches(self.n_slots, self.max_len)
        return self.pipeline.insert_slots(toks, lens, slot_ids)

    def _decode_all(self, toks, pos):
        return self.pipeline.decode_slots(toks, pos)
