"""Paged KV-cache bookkeeping: a fixed pool of cache BLOCKS per stage and a
per-request BlockTable mapping logical token positions to physical blocks.

The paper's engine (and our PR-1 slot engine) pre-allocated one contiguous
``max_len`` cache row per slot, so a replica's concurrency was capped by the
WORST-CASE sequence length — a large-HBM stage could not hold more in-flight
requests than its smallest peer. Paging (vLLM-style; cf. the HexGen-2 view
of KV state as a movable first-class resource) allocates fixed-size blocks
on demand: admission needs only the prompt's blocks plus headroom, decode
grows tables one block at a time, and when the pool runs dry the engine
preempts a slot by recompute (free its blocks, requeue the request).

Block ids are plain ints into per-stage page arrays
``(n_blocks, block_size, heads, head_dim)`` (models.model.init_paged_cache).
Block 0 is reserved as the NULL/trash block: unallocated table entries point
at it, compile-shape padding rows scatter into it, and it is never read
(attention masks positions >= kv_len).

Refcounts back PREFIX SHARING: ``PrefixIndex`` maps a chained hash of each
block-aligned token chunk to the resident physical block holding its K/V,
holding one reference per indexed block so cached prefixes survive their
original request. Admission matches a new prompt against the index, aliases
the hit blocks (``acquire`` increfs), and prefills only the cold suffix;
writing into a still-shared block first goes through ``BlockTable.writable``
(copy-on-write). Blocks whose only remaining reference is the index's are
evictable, LRU-first, when the pool runs dry.

Everything here is host-side Python — no jax. The arrays handed to jitted
stage functions come from ``BlockTable.as_array``; page copies for COW are
applied on device by the pipeline (``AsymmetricPipeline.copy_pages``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

NULL_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold n_tokens (>= 0)."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Fixed pool of cache blocks with a free list and per-block refcounts.

    Block 0 is reserved (NULL/trash) and never handed out; ``n_blocks``
    counts it, so a pool of n_blocks has n_blocks - 1 usable blocks.
    """

    def __init__(self, n_blocks: int, block_size: int):
        assert n_blocks >= 2, "pool needs at least the null block + one"
        assert block_size >= 1
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, n_blocks))
        self._ref = np.zeros(n_blocks, np.int32)
        self._ref[NULL_BLOCK] = 1          # pinned forever
        # optional PrefixIndex notified on 1<->2 ref transitions so it can
        # keep its evictable count O(1) (set by PrefixIndex.__init__)
        self.observer = None
        # occupancy high-water mark, exported as the kv_pool_peak_blocks
        # gauge (repro.obs.metrics)
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """All-or-nothing allocation of n blocks; None when the pool is dry."""
        if n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for b in out:
            assert self._ref[b] == 0, b
            self._ref[b] = 1
        used = self.n_used
        if used > self.peak_used:
            self.peak_used = used
        return out

    def incref(self, bid: int) -> None:
        assert bid != NULL_BLOCK and self._ref[bid] > 0, bid
        self._ref[bid] += 1
        if self._ref[bid] == 2 and self.observer is not None:
            self.observer._ref_rose_above_one(bid)

    def free(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at zero."""
        if bid == NULL_BLOCK:
            return
        assert self._ref[bid] > 0, f"double free of block {bid}"
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
        elif self._ref[bid] == 1 and self.observer is not None:
            self.observer._ref_fell_to_one(bid)

    def ref(self, bid: int) -> int:
        return int(self._ref[bid])


@dataclasses.dataclass
class BlockTable:
    """One request's logical->physical block map within a single pool."""

    pool: BlockPool
    blocks: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.pool.block_size

    def allocate_tokens(self, n_tokens: int) -> bool:
        """Grow the table to hold n_tokens total; all-or-nothing."""
        need = blocks_for_tokens(n_tokens, self.pool.block_size) \
            - len(self.blocks)
        if need <= 0:
            return True
        got = self.pool.alloc(need)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def ensure(self, pos: int) -> bool:
        """Make position `pos` writable (allocate-on-decode growth)."""
        return self.allocate_tokens(pos + 1)

    def release(self) -> None:
        for b in self.blocks:
            self.pool.free(b)
        self.blocks.clear()

    def truncate(self, n_tokens: int) -> int:
        """Shrink the table to exactly the blocks holding ``n_tokens`` —
        the SPECULATIVE-PAGE ROLLBACK: a multi-token verification step
        allocates blocks for the whole candidate chunk up front, and when
        acceptance commits only a prefix, the trailing blocks (whose every
        position lies past the committed length) go back to the pool.
        Dropping is one reference like any release, so a trailing block
        that is aliased elsewhere (a prefix-index entry, a fork) stays
        resident for its other holders — COW- and prefix-index-safe by
        construction. Stale candidate K/V in the KEPT tail block is
        masked by kv_len and overwritten by the next chunk. Returns the
        number of blocks dropped from this table."""
        keep = blocks_for_tokens(n_tokens, self.pool.block_size)
        dropped = 0
        while len(self.blocks) > keep:
            self.pool.free(self.blocks.pop())
            dropped += 1
        return dropped

    def adopt(self, blocks: Sequence[int]) -> None:
        """Append already-referenced block ids to the table, taking over
        their references — the landing step of prefix aliasing
        (``PrefixIndex.acquire``) and KV migration placement, where the
        references were created on this table's behalf before the blocks
        reach it. The table releases them like any block it allocated."""
        assert not (set(blocks) & set(self.blocks)), "block adopted twice"
        self.blocks.extend(int(b) for b in blocks)

    def fork(self) -> "BlockTable":
        """Alias every block (refcount++) — the prefix-sharing enabler.
        Callers must copy-on-write before mutating a shared block."""
        for b in self.blocks:
            self.pool.incref(b)
        return BlockTable(self.pool, list(self.blocks))

    def writable(self, block_idx: int
                 ) -> Union[None, Tuple[int, int], bool]:
        """Copy-on-write: make ``blocks[block_idx]`` exclusively owned.

        Returns None when the block is already exclusive (ref == 1), a
        ``(src, dst)`` pair when it was aliased onto a fresh block — the
        caller must copy the page contents src -> dst on device and drop
        happens here (the shared block loses this table's reference) — or
        False when the pool has no free block for the copy (caller evicts
        or preempts and retries)."""
        bid = self.blocks[block_idx]
        assert bid != NULL_BLOCK, "COW on the null block"
        if self.pool.ref(bid) == 1:
            return None
        got = self.pool.alloc(1)
        if got is None:
            return False
        self.blocks[block_idx] = got[0]
        self.pool.free(bid)          # drop OUR reference; sharers keep theirs
        return (bid, got[0])

    def as_array(self, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 padded with the NULL block."""
        assert len(self.blocks) <= max_blocks, (len(self.blocks), max_blocks)
        out = np.full(max_blocks, NULL_BLOCK, np.int32)
        out[:len(self.blocks)] = self.blocks
        return out

    def gather_positions(self, n_tokens: int) -> np.ndarray:
        """Flat physical slot index (block * block_size + offset) of each of
        the first n_tokens logical positions — the host-side round-trip
        oracle the property tests check gather/scatter against."""
        bs = self.pool.block_size
        pos = np.arange(n_tokens)
        return np.asarray(self.blocks, np.int64)[pos // bs] * bs + pos % bs


# ---------------------------------------------------------------------------
# Prefix index (vLLM-style automatic prefix caching)
# ---------------------------------------------------------------------------

def chunk_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Chained hash of each FULL block-aligned token chunk: hash j covers
    tokens [0, (j+1)*block_size), so equal hash <=> equal prefix (modulo
    hash collisions, negligible for host-side dedup). Partial tail chunks
    are never hashed — only whole blocks are shareable."""
    out: List[int] = []
    h = 0
    for j in range(len(tokens) // block_size):
        chunk = tuple(int(t) for t in
                      tokens[j * block_size:(j + 1) * block_size])
        h = hash((h, chunk))
        out.append(h)
    return out


class PrefixIndex:
    """Chained-hash -> resident physical block, one per FULL prompt chunk.

    The index holds ONE reference on every block it maps, so a cached
    prefix outlives the request that wrote it. A block whose only
    remaining reference is the index's is EVICTABLE; ``evict`` frees
    such blocks LRU-first when the pool runs dry. ``acquire`` increfs
    matched blocks on behalf of a new request (which releases them through
    its BlockTable like any other block). Stale aliasing is impossible by
    construction: a mapped block can only reach refcount zero through
    ``evict``/``clear``, which removes the mapping first.
    """

    def __init__(self, pool: BlockPool):
        assert pool.observer is None, "one PrefixIndex per pool"
        self.pool = pool
        pool.observer = self
        self._block_of: dict = {}            # chain hash -> block id
        self._hash_of: dict = {}             # block id -> chain hash
        self._lru: OrderedDict = OrderedDict()   # block id -> None, LRU order
        # indexed blocks whose ONLY reference is the index's, maintained
        # O(1) via the pool's ref-transition notifications — admission
        # reads this every loop iteration (capacity counts evictable
        # blocks as free), so a per-call scan would be O(pool) steady work
        self._evictable = 0
        # optional demotion hook: ``spill(chain_hash, block_id)`` runs for
        # every evicted block BEFORE its pool slot is freed (page contents
        # still valid on device), turning eviction into device -> host
        # demotion when a HostPagePool is wired (serving.continuous)
        self.spill = None

    # ---- BlockPool observer hooks (1 <-> 2 ref transitions) -------------
    def _ref_fell_to_one(self, bid: int) -> None:
        if bid in self._hash_of:
            self._evictable += 1

    def _ref_rose_above_one(self, bid: int) -> None:
        if bid in self._hash_of:
            self._evictable -= 1

    def __len__(self) -> int:
        return len(self._block_of)

    def match_len(self, hashes: Sequence[int]) -> int:
        """Length (in blocks) of the longest indexed prefix of `hashes`."""
        n = 0
        for h in hashes:
            if h not in self._block_of:
                break
            n += 1
        return n

    def lookup(self, h: int) -> Optional[int]:
        """Resident block id for chain hash `h` (None = not indexed); no
        refcount or LRU side effects — tier planning and cluster export
        peek without claiming."""
        return self._block_of.get(h)

    def acquire(self, hashes: Sequence[int]) -> List[int]:
        """Alias the indexed prefix `hashes` (all must be resident):
        increfs every block on the caller's behalf and marks it
        recently-used. The caller owns the new references (release via
        BlockTable.release / pool.free)."""
        blocks = []
        for h in hashes:
            bid = self._block_of[h]
            self.pool.incref(bid)
            blocks.append(bid)
        # LRU-touch in REVERSE chain order so the chain's HEAD ends up the
        # most recently used. Chained hashes only ever match head-first, so
        # eviction must trim a chain TAIL-first: freeing the head would
        # orphan every deeper block (unmatched forever yet still resident).
        # In particular a partial re-hit — a short head that keeps hitting
        # under a long cold tail — refreshes exactly the matched head,
        # leaving the stale tail as the eviction victim.
        for bid in reversed(blocks):
            self._lru.move_to_end(bid)
        return blocks

    def register(self, hashes: Sequence[int], blocks: Sequence[int]) -> int:
        """Index freshly written blocks under their chunk hashes (incref —
        the index's own reference). Hashes already resident are skipped:
        the first writer stays canonical, a duplicate block is simply not
        indexed. Returns the number of new entries."""
        added = 0
        new: List[int] = []
        for h, bid in zip(hashes, blocks):
            if h in self._block_of:
                continue
            assert bid not in self._hash_of, (bid, "indexed twice")
            self.pool.incref(bid)
            self._block_of[h] = bid
            self._hash_of[bid] = h
            self._lru[bid] = None
            new.append(bid)
            added += 1
        # same reverse-order touch as acquire: heads newer than tails, so
        # pressure trims chains from the deep end
        for bid in reversed(new):
            self._lru.move_to_end(bid)
        return added

    def n_evictable(self) -> int:
        """Blocks reclaimable right now (referenced only by the index)."""
        return self._evictable

    def indexed_blocks(self) -> List[int]:
        """Every block id the index currently holds a reference on
        (KVSAN's refcount-conservation audit enumerates these)."""
        return list(self._hash_of)

    def evict(self, n: int) -> int:
        """Free up to `n` evictable blocks, least-recently-used first;
        returns how many were freed (their pool slots are reusable)."""
        freed = 0
        for bid in list(self._lru):
            if freed >= n:
                break
            if self.pool.ref(bid) != 1:
                continue                      # still aliased by a request
            h = self._hash_of.pop(bid)
            del self._block_of[h]
            del self._lru[bid]
            self._evictable -= 1
            if self.spill is not None:
                self.spill(h, bid)            # demote before the slot frees
            self.pool.free(bid)               # 1 -> 0: back to the free list
            freed += 1
        return freed

    def clear(self) -> None:
        """Drop every cached prefix (frees the index's references). A reset,
        not pressure: nothing spills to the host tier."""
        for bid in list(self._lru):
            h = self._hash_of.pop(bid)
            del self._block_of[h]
            del self._lru[bid]
            self.pool.free(bid)
        self._evictable = 0


# ---------------------------------------------------------------------------
# Host-memory page tier (device -> host demotion)
# ---------------------------------------------------------------------------

class HostPagePool:
    """Host-memory (CPU DRAM) tier for demoted prefix pages.

    Device eviction under pool pressure DEMOTES a prefix block's page
    payload here instead of deleting it, keyed by the same chained chunk
    hash the ``PrefixIndex`` uses; a later prompt that matches the hash
    PROMOTES the payload back into a fresh device block (``get`` pops —
    every page lives in exactly one tier). Capacity is counted in blocks
    and enforced LRU, like the device index but with true deletion at the
    bottom of the hierarchy (``on_evict`` lets the cluster directory track
    the final drop).

    The payload is opaque to the pool: the engine stores one numpy pytree
    per stage layer (``{"k","v"[,"k_scale","v_scale"]}``, leading axis =
    one block) captured at POOL precision, so quantized pages (PR 6) spill
    at their narrow width and re-land verbatim.
    """

    def __init__(self, capacity: int, block_size: int):
        assert capacity >= 1, "host tier needs at least one block"
        self.capacity = capacity
        self.block_size = block_size
        self._pages: OrderedDict = OrderedDict()   # chain hash -> payload
        # callback(chain_hash) when the LRU bound drops an entry — the page
        # has now left the replica entirely (directory unpublish)
        self.on_evict = None
        self.demotions = 0         # payloads accepted (device -> host)
        self.promotions = 0        # payloads popped back out (host -> device)
        self.evictions = 0         # payloads dropped at the LRU bound

    def __len__(self) -> int:
        return len(self._pages)

    def __contains__(self, h) -> bool:
        return h in self._pages

    def match_len(self, hashes: Sequence[int]) -> int:
        """Length (in blocks) of the longest resident prefix of `hashes`."""
        n = 0
        for h in hashes:
            if h not in self._pages:
                break
            n += 1
        return n

    def put(self, h: int, payload) -> None:
        """Demote a page payload under its chain hash; over capacity the
        least-recently-touched payload is dropped (true eviction)."""
        if h in self._pages:
            self._pages.move_to_end(h)     # refresh, keep first demotion
            return
        self._pages[h] = payload
        self.demotions += 1
        while len(self._pages) > self.capacity:
            old, _ = self._pages.popitem(last=False)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old)

    def get(self, h: int):
        """Promote: POP the payload for `h` (None on miss). Popping keeps
        the one-tier invariant — the caller re-registers the page on
        device, so a host copy left behind would alias it."""
        payload = self._pages.pop(h, None)
        if payload is not None:
            self.promotions += 1
        return payload

    def peek(self, h: int):
        """Read without promoting (cluster export: the payload stays
        host-resident on this replica while a COPY migrates to a peer)."""
        return self._pages.get(h)

    def restore(self, h: int, payload) -> None:
        """Undo a ``get`` whose promotion could not allocate a device
        block: the payload returns to the host tier, counter-neutral."""
        self.promotions -= 1
        self.demotions -= 1
        self.put(h, payload)

    def discard(self, h: int) -> None:
        """Drop a stale host copy without eviction accounting — the page
        was re-registered on device (one-tier invariant), the host copy
        no longer exists anywhere."""
        self._pages.pop(h, None)

    def hashes(self) -> List[int]:
        """Resident chain hashes, LRU order (KVSAN's tier audit)."""
        return list(self._pages)

    def nbytes(self) -> int:
        return int(sum(a.nbytes for payload in self._pages.values()
                       for lkv in payload for a in lkv.values()))
