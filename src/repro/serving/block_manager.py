"""Paged KV-cache bookkeeping: a fixed pool of cache BLOCKS per stage and a
per-request BlockTable mapping logical token positions to physical blocks.

The paper's engine (and our PR-1 slot engine) pre-allocated one contiguous
``max_len`` cache row per slot, so a replica's concurrency was capped by the
WORST-CASE sequence length — a large-HBM stage could not hold more in-flight
requests than its smallest peer. Paging (vLLM-style; cf. the HexGen-2 view
of KV state as a movable first-class resource) allocates fixed-size blocks
on demand: admission needs only the prompt's blocks plus headroom, decode
grows tables one block at a time, and when the pool runs dry the engine
preempts a slot by recompute (free its blocks, requeue the request).

Block ids are plain ints into per-stage page arrays
``(n_blocks, block_size, heads, head_dim)`` (models.model.init_paged_cache).
Block 0 is reserved as the NULL/trash block: unallocated table entries point
at it, compile-shape padding rows scatter into it, and it is never read
(attention masks positions >= kv_len). Refcounts exist so a future
prefix-sharing / fork path can alias blocks copy-on-write; the serving
engine today only ever holds one reference per block.

Everything here is host-side Python — no jax. The arrays handed to jitted
stage functions come from ``BlockTable.as_array``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np

NULL_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold n_tokens (>= 0)."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Fixed pool of cache blocks with a free list and per-block refcounts.

    Block 0 is reserved (NULL/trash) and never handed out; ``n_blocks``
    counts it, so a pool of n_blocks has n_blocks - 1 usable blocks.
    """

    def __init__(self, n_blocks: int, block_size: int):
        assert n_blocks >= 2, "pool needs at least the null block + one"
        assert block_size >= 1
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, n_blocks))
        self._ref = np.zeros(n_blocks, np.int32)
        self._ref[NULL_BLOCK] = 1          # pinned forever

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """All-or-nothing allocation of n blocks; None when the pool is dry."""
        if n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for b in out:
            assert self._ref[b] == 0, b
            self._ref[b] = 1
        return out

    def incref(self, bid: int) -> None:
        assert bid != NULL_BLOCK and self._ref[bid] > 0, bid
        self._ref[bid] += 1

    def free(self, bid: int) -> None:
        """Drop one reference; the block returns to the free list at zero."""
        if bid == NULL_BLOCK:
            return
        assert self._ref[bid] > 0, f"double free of block {bid}"
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)

    def ref(self, bid: int) -> int:
        return int(self._ref[bid])


@dataclasses.dataclass
class BlockTable:
    """One request's logical->physical block map within a single pool."""

    pool: BlockPool
    blocks: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.pool.block_size

    def allocate_tokens(self, n_tokens: int) -> bool:
        """Grow the table to hold n_tokens total; all-or-nothing."""
        need = blocks_for_tokens(n_tokens, self.pool.block_size) \
            - len(self.blocks)
        if need <= 0:
            return True
        got = self.pool.alloc(need)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def ensure(self, pos: int) -> bool:
        """Make position `pos` writable (allocate-on-decode growth)."""
        return self.allocate_tokens(pos + 1)

    def release(self) -> None:
        for b in self.blocks:
            self.pool.free(b)
        self.blocks.clear()

    def fork(self) -> "BlockTable":
        """Alias every block (refcount++) — the prefix-sharing enabler.
        Callers must copy-on-write before mutating a shared block."""
        for b in self.blocks:
            self.pool.incref(b)
        return BlockTable(self.pool, list(self.blocks))

    def as_array(self, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 padded with the NULL block."""
        assert len(self.blocks) <= max_blocks, (len(self.blocks), max_blocks)
        out = np.full(max_blocks, NULL_BLOCK, np.int32)
        out[:len(self.blocks)] = self.blocks
        return out

    def gather_positions(self, n_tokens: int) -> np.ndarray:
        """Flat physical slot index (block * block_size + offset) of each of
        the first n_tokens logical positions — the host-side round-trip
        oracle the property tests check gather/scatter against."""
        bs = self.pool.block_size
        pos = np.arange(n_tokens)
        return np.asarray(self.blocks, np.int64)[pos // bs] * bs + pos % bs
