"""Asymmetric pipeline executor (Contribution 1, §3.2).

Each stage owns a disjoint device subset with its OWN tensor-parallel degree
and its OWN contiguous span of layers. Per stage we build a 1-axis
``jax.sharding.Mesh`` ("model"), place that stage's parameters with the
Megatron specs from models.shardings, and jit prefill/decode stage functions
with in/out shardings. Activations move between stages with
``jax.device_put`` onto the next stage's mesh — the paper's leader-GPU
relay + intra-group broadcast falls out of the resharding copy (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, ModelConfig
from repro.models import model as M
from repro.models import layers, shardings


def _rep(mesh):
    return NamedSharding(mesh, P())


class StageExecutor:
    """One pipeline stage: layers [lo, hi) on `devices` with TP=len(devices)."""

    def __init__(self, cfg: ModelConfig, params, lo: int, hi: int,
                 devices: Sequence[jax.Device], *, is_first: bool,
                 is_last: bool):
        self.cfg = cfg
        self.lo, self.hi = lo, hi
        self.is_first, self.is_last = is_first, is_last
        self.tp = len(devices)
        self.mesh = Mesh(np.array(devices), ("model",))
        self.kinds = [cfg.layer_kind(i) for i in range(lo, hi)]

        # place per-layer params on this stage's mesh
        self.layer_params = []
        for i in range(lo, hi):
            lp = M.slice_layer_params(cfg, params, i)
            spec = shardings.param_specs(
                cfg, {"blocks": {f"sub{M.layer_sub_index(cfg, i)[1]}":
                                 jax.tree.map(lambda x: x[None], lp)}},
                tp=self.tp)["blocks"][f"sub{M.layer_sub_index(cfg, i)[1]}"]
            # strip the leading None of the stacked spec
            spec = jax.tree.map(
                lambda s: P(*s[1:]), spec,
                is_leaf=lambda s: isinstance(s, P))
            placed = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
                lp, spec)
            self.layer_params.append(placed)

        self.head_params = None
        if is_first or is_last:
            hp = {"embed": params["embed"],
                  "final_norm": params["final_norm"]}
            if "lm_head" in params:
                hp["lm_head"] = params["lm_head"]
            if cfg.is_encoder_decoder and is_first:
                hp["encoder"] = params["encoder"]
            self.head_params = jax.device_put(hp, _rep(self.mesh))

        self._prefill_jit = jax.jit(
            partial(self._stage_seq, mode="prefill"),
            static_argnames=())
        self._decode_jit = jax.jit(self._stage_decode, donate_argnums=(1,))
        self._decode_paged_jit = jax.jit(self._stage_decode_paged,
                                         donate_argnums=(1,))
        self._context_paged_jit = jax.jit(self._stage_context_paged,
                                          donate_argnums=(1,))
        self._verify_paged_jit = jax.jit(self._stage_verify_paged,
                                         donate_argnums=(1,))
        self._copy_pages_jit = jax.jit(self._stage_copy_pages,
                                       donate_argnums=(0,))
        self._scatter_pages_jit = jax.jit(self._stage_scatter_pages,
                                          donate_argnums=(0,))

    @property
    def has_attn(self) -> bool:
        return ATTN in self.kinds

    # ---- stage bodies (pure) --------------------------------------------
    def _stage_seq(self, x, caches, positions, kv_start, valid, enc_out,
                   lens=None, *, mode):
        new_caches = []
        for kind, lp, sc in zip(self.kinds, self.layer_params, caches):
            x, nc, _ = M.apply_sublayer_seq(
                self.cfg, kind, lp, x, sc, positions=positions,
                kv_start=kv_start, valid=valid, enc_out=enc_out, mode=mode,
                lens=lens)
            new_caches.append(nc)
        return x, new_caches

    def _stage_decode(self, x, caches, pos, kv_start, enc_out):
        new_caches = []
        for kind, lp, sc in zip(self.kinds, self.layer_params, caches):
            x, nc = M.apply_sublayer_decode(self.cfg, kind, lp, x, sc,
                                            pos=pos, kv_start=kv_start)
            new_caches.append(nc)
        return x, new_caches

    def _stage_decode_paged(self, x, caches, pos, block_tables):
        new_caches = []
        for kind, lp, sc in zip(self.kinds, self.layer_params, caches):
            x, nc = M.apply_sublayer_decode_paged(
                self.cfg, kind, lp, x, sc, pos=pos,
                block_tables=block_tables)
            new_caches.append(nc)
        return x, new_caches

    def _stage_context_paged(self, x, caches, positions, q_len,
                             block_tables):
        new_caches = []
        for kind, lp, sc in zip(self.kinds, self.layer_params, caches):
            x, nc = M.apply_sublayer_context_paged(
                self.cfg, kind, lp, x, sc, positions=positions, q_len=q_len,
                block_tables=block_tables)
            new_caches.append(nc)
        return x, new_caches

    def _stage_verify_paged(self, x, caches, positions, q_len,
                            block_tables):
        new_caches = []
        for kind, lp, sc in zip(self.kinds, self.layer_params, caches):
            x, nc = M.apply_sublayer_verify_paged(
                self.cfg, kind, lp, x, sc, positions=positions, q_len=q_len,
                block_tables=block_tables)
            new_caches.append(nc)
        return x, new_caches

    def _stage_copy_pages(self, caches, src, dst):
        """Duplicate page contents src -> dst in every attention layer's
        pools (copy-on-write). Donated + jitted so XLA updates the pools
        in place instead of materializing a copy of each one."""
        return [M.copy_cache_pages(c, src, dst, stacked=False)
                for c in caches]

    def _stage_scatter_pages(self, caches, dst, payload):
        """Write migrated-in page payloads (one {"k","v"[,"k_scale",
        "v_scale"]} pytree per layer of this stage, leading axis = len(dst)
        blocks) into the pools at block ids `dst` (KV migration landing).
        Quantized pools ship the payload at wire width plus the float32
        scale leaves — no requantization on landing."""
        out = []
        for c, p in zip(caches, payload):
            c = dict(c)
            for n in p:
                c[n] = c[n].at[dst].set(p[n].astype(c[n].dtype))
            out.append(c)
        return out

    # ---- cache ------------------------------------------------------------
    def make_caches(self, batch: int, max_len: int):
        out = []
        for i in range(self.lo, self.hi):
            c = M.init_layer_cache(self.cfg, i, batch, max_len)
            out.append(jax.device_put(c, _rep(self.mesh)))
        return out

    def make_paged_caches(self, n_blocks: int, block_size: int,
                          n_slots: int, *, kv_dtype=None,
                          kv_guard_layers=()):
        """Per-layer paged caches; this stage's attention layers all share
        ONE physical pool id-space of `n_blocks` blocks (each layer holds
        its own page arrays, addressed by the same block table).
        `kv_dtype` selects the pool storage precision (None = model
        default); layers in `kv_guard_layers` (GLOBAL indices) stay at
        model precision regardless (quality guard)."""
        out = []
        for i in range(self.lo, self.hi):
            c = M.init_layer_paged_cache(self.cfg, i, n_blocks, block_size,
                                         n_slots, kv_dtype=kv_dtype,
                                         kv_guard_layers=kv_guard_layers)
            out.append(jax.device_put(c, _rep(self.mesh)))
        return out


def slot_mode_supported(cfg) -> bool:
    """Slot-based continuous batching drives uniform text decoders; SWA
    ring caches need uniform positions and encoder-decoder/VLM prompts
    carry per-request modality state."""
    return not (cfg.swa_window or cfg.is_encoder_decoder
                or cfg.num_image_tokens)


def context_mode_supported(cfg) -> bool:
    """Prefix caching and chunked prefill run prompts through the paged
    CONTEXT path, which needs every sublayer to be attention: a recurrent
    sublayer's state is a running summary of everything before it — there
    is no per-block piece to alias (prefix sharing) or resume from
    (chunked prefill). Hybrid stacks keep one-shot prefill."""
    return slot_mode_supported(cfg) and all(
        cfg.layer_kind(i) == ATTN for i in range(cfg.num_layers))


class AsymmetricPipeline:
    """A full model replica as a chain of StageExecutors."""

    def __init__(self, cfg: ModelConfig, params, stage_layers: Sequence[int],
                 stage_devices: Sequence[Sequence[jax.Device]]):
        assert sum(stage_layers) == cfg.num_layers
        self.cfg = cfg
        self.stages: List[StageExecutor] = []
        lo = 0
        for si, (nl, devs) in enumerate(zip(stage_layers, stage_devices)):
            self.stages.append(StageExecutor(
                cfg, params, lo, lo + nl, devs,
                is_first=(si == 0), is_last=(si == len(stage_layers) - 1)))
            lo += nl
        self.caches = None
        self._pos = 0
        self._kv_start = None
        # slot-mode state (init_slot_caches): per-stage cache pools
        self.slot_caches = None
        self.n_slots = 0
        self.slot_len = 0
        # paged slot-mode state (init_paged_caches): per-stage page pools
        self.paged_caches = None
        self.block_size = 0
        self.stage_blocks: List[int] = []
        self.kv_dtype: Optional[str] = None
        self.kv_guard_layers: tuple = ()

    # ---- embedding / head on first / last stage ---------------------------
    def _embed(self, tokens, batch_extras):
        s0 = self.stages[0]
        hp = s0.head_params
        x = hp["embed"][tokens]
        if self.cfg.family == "vlm":
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        if self.cfg.num_image_tokens:
            x = jnp.concatenate(
                [batch_extras["image_embeds"].astype(x.dtype), x], axis=1)
        return x

    def _head(self, x):
        sl = self.stages[-1]
        hp = sl.head_params
        x = M._norm(self.cfg, hp["final_norm"], x)
        if self.cfg.tie_embeddings:
            return x @ hp["embed"].T
        return M.mm(x, hp["lm_head"])

    # ---- public API --------------------------------------------------------
    def prefill(self, tokens: np.ndarray, *, kv_start=None, max_new: int = 32,
                batch_extras=None):
        """tokens (b, s) left-padded; returns last-position logits (b, V)."""
        cfg = self.cfg
        b, s = tokens.shape
        total = s + cfg.num_image_tokens
        self.caches = [st.make_caches(b, total + max_new)
                       for st in self.stages]
        self._kv_start = None if kv_start is None else jnp.asarray(kv_start)
        batch_extras = batch_extras or {}

        enc_out = None
        if cfg.is_encoder_decoder:
            hp = self.stages[0].head_params
            enc_out = M._encoder_forward(cfg, hp, batch_extras["enc_frames"])

        x = self._embed(jnp.asarray(tokens), batch_extras)
        positions = jnp.arange(total)[None].repeat(b, 0)
        if cfg.is_encoder_decoder and cfg.rope_theta == 0.0:
            x = x + layers.sinusoidal_positions(positions, cfg.d_model
                                                ).astype(x.dtype)
        valid = None
        if self._kv_start is not None:
            valid = (jnp.arange(total)[None, :]
                     >= self._kv_start[:, None]).astype(jnp.int32)

        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                eo = None
                if enc_out is not None:
                    eo = jax.device_put(enc_out, _rep(st.mesh))
                x, self.caches[si] = st._prefill_jit(
                    x, self.caches[si], positions, self._kv_start, valid, eo)
        self._pos = total
        return np.asarray(self._head(x[:, -1:, :])[:, 0])

    def _embed_decode_tokens(self, tokens, positions):
        """Single-token decode embedding (b,1,d): embed lookup + family
        scaling + sinusoidal positions where the architecture uses them."""
        cfg = self.cfg
        x = self.stages[0].head_params["embed"][tokens[:, None]]
        if cfg.family == "vlm":
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        if cfg.is_encoder_decoder and cfg.rope_theta == 0.0:
            x = x + layers.sinusoidal_positions(positions[:, None],
                                                cfg.d_model).astype(x.dtype)
        return x

    def decode_step(self, tokens: np.ndarray):
        """tokens (b,) -> next-position logits (b, V)."""
        tokens = jnp.asarray(tokens)
        x = self._embed_decode_tokens(
            tokens, jnp.full((tokens.shape[0],), self._pos))
        pos = jnp.int32(self._pos)       # traced: no retrace per step
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                x, self.caches[si] = st._decode_jit(
                    x, self.caches[si], pos, self._kv_start, None)
        self._pos += 1
        return np.asarray(self._head(x)[:, 0])

    def generate(self, tokens: np.ndarray, *, max_new: int, kv_start=None,
                 batch_extras=None, greedy: bool = True):
        """Returns (b, max_new) generated ids."""
        logits = self.prefill(tokens, kv_start=kv_start, max_new=max_new,
                              batch_extras=batch_extras)
        out = []
        for _ in range(max_new):
            nxt = logits.argmax(-1).astype(np.int32)
            out.append(nxt)
            logits = self.decode_step(nxt)
        return np.stack(out, axis=1)

    # ---- slot mode (continuous batching) -----------------------------------
    # Each stage owns a pre-allocated cache POOL whose batch rows are decode
    # slots (allocated lazily on first insert). Arriving requests are prefilled jointly (right-padded, per-row
    # lengths) through the stage chain on scratch caches and their rows
    # scattered into free pool slots; decode iterations carry per-slot
    # positions so slots at different depths share one jitted step.

    def init_slot_caches(self, n_slots: int, max_len: int) -> None:
        assert slot_mode_supported(self.cfg), \
            "slot mode needs uniform text decode (SWA ring cache / " \
            "encoder-decoder / VLM); use static batching"
        self.n_slots = n_slots
        self.slot_len = max_len
        self.slot_caches = [st.make_caches(n_slots, max_len)
                            for st in self.stages]

    def insert_slots(self, tokens: np.ndarray, lens: np.ndarray,
                     slot_ids: Sequence[int]) -> np.ndarray:
        """Joint prefill of right-padded prompts `tokens` (m, P) with real
        lengths `lens` (m,), scattering each row's caches into pool slot
        `slot_ids[i]`. Returns each row's last-real-token logits (m, V).

        Right padding keeps every row's token positions identical to
        isolated generation (bit-identity), and leaves recurrent-state
        caches holding exactly the post-prompt state; trailing garbage in
        attention K/V beyond lens[i] is masked by kv_len during decode and
        progressively overwritten as the slot decodes.
        """
        assert self.slot_caches is not None, "call init_slot_caches first"
        m = len(slot_ids)          # rows beyond m are compile-shape padding
        b, P = tokens.shape
        lens = jnp.asarray(lens, jnp.int32)
        x = self._embed(jnp.asarray(tokens), {})
        positions = jnp.arange(P)[None].repeat(b, 0)
        valid = (jnp.arange(P)[None, :] < lens[:, None]).astype(jnp.int32)
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                scratch = st.make_caches(b, self.slot_len)
                x, rows = st._prefill_jit(x, scratch, positions, None,
                                          valid, None, lens)
                self.slot_caches[si] = [
                    M.scatter_cache_rows(pool,
                                         jax.tree.map(lambda r: r[:m], row),
                                         slot_ids)
                    for pool, row in zip(self.slot_caches[si], rows)]
        x_last = x[jnp.arange(m), lens[:m] - 1][:, None]
        return np.asarray(self._head(x_last)[:, 0])

    def decode_slots(self, tokens: np.ndarray,
                     positions: np.ndarray) -> np.ndarray:
        """One decode iteration over ALL slots. tokens (n_slots,) next input
        token per slot; positions (n_slots,) its absolute position. Free
        slots decode garbage that is simply discarded. Returns (n_slots, V).
        """
        pos = jnp.asarray(positions, jnp.int32)
        x = self._embed_decode_tokens(jnp.asarray(tokens), pos)
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                x, self.slot_caches[si] = st._decode_jit(
                    x, self.slot_caches[si], pos, None, None)
        return np.asarray(self._head(x)[:, 0])

    # ---- paged slot mode ---------------------------------------------------
    # Same joint-iteration contract as slot mode, but each stage owns a
    # BLOCK pool sized independently (∝ its devices' memory — the
    # asymmetric-capacity point) instead of n_slots pre-cut max_len rows.
    # Block allocation/preemption policy lives in the engine
    # (serving.continuous.PagedPipelineBatcher + serving.block_manager);
    # the pipeline only moves tensors.

    def init_paged_caches(self, n_slots: int, max_len: int, *,
                          block_size: int = 16,
                          stage_blocks: Optional[Sequence[int]] = None,
                          kv_dtype: Optional[str] = None,
                          kv_guard_layers: Sequence[int] = ()
                          ) -> None:
        """Per-stage page pools. `stage_blocks[si]` is stage si's pool size
        in blocks (including the reserved null block); None sizes every
        stage for full occupancy (n_slots * max_len tokens), which makes
        paged serving a drop-in replacement with zero preemptions.
        `kv_dtype` in {"fp32","bf16","int8","fp8"} selects pool precision
        (None = model default dtype, pre-quantization layout);
        `kv_guard_layers` pins those GLOBAL layer indices at model
        precision even under a quantized kv_dtype."""
        assert slot_mode_supported(self.cfg), \
            "paged slot mode needs uniform text decode (SWA ring cache / " \
            "encoder-decoder / VLM); use static batching"
        assert max_len % block_size == 0, (max_len, block_size)
        self.n_slots = n_slots
        self.slot_len = max_len
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        self.kv_guard_layers = tuple(kv_guard_layers)
        full = n_slots * (max_len // block_size) + 1
        if stage_blocks is None:
            stage_blocks = [full] * len(self.stages)
        self.stage_blocks = list(stage_blocks)
        assert len(self.stage_blocks) == len(self.stages)
        self.paged_caches = [
            st.make_paged_caches(nb, block_size, n_slots,
                                 kv_dtype=kv_dtype,
                                 kv_guard_layers=self.kv_guard_layers)
            for st, nb in zip(self.stages, self.stage_blocks)]

    def insert_slots_paged(self, tokens: np.ndarray, lens: np.ndarray,
                           slot_ids: Sequence[int],
                           stage_dest: Sequence[np.ndarray]) -> np.ndarray:
        """Joint right-padded prefill (same compile shapes and math as
        ``insert_slots``) whose attention rows scatter into stage si's pages
        at ``stage_dest[si]`` ((m * max_blocks,) physical page per logical
        block, row-major; null-page entries absorb the padding) and whose
        recurrent rows scatter by slot id. Returns last-real-token logits
        (m, V)."""
        assert self.paged_caches is not None, "call init_paged_caches first"
        m = len(slot_ids)          # rows beyond m are compile-shape padding
        b, P = tokens.shape
        lens = jnp.asarray(lens, jnp.int32)
        x = self._embed(jnp.asarray(tokens), {})
        positions = jnp.arange(P)[None].repeat(b, 0)
        valid = (jnp.arange(P)[None, :] < lens[:, None]).astype(jnp.int32)
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                scratch = st.make_caches(b, self.slot_len)
                x, rows = st._prefill_jit(x, scratch, positions, None,
                                          valid, None, lens)
                dest = jnp.asarray(stage_dest[si], jnp.int32)
                self.paged_caches[si] = [
                    M.scatter_cache_rows_paged(
                        pool, jax.tree.map(lambda r: r[:m], row),
                        slot_ids, dest)
                    for pool, row in zip(self.paged_caches[si], rows)]
        x_last = x[jnp.arange(m), lens[:m] - 1][:, None]
        return np.asarray(self._head(x_last)[:, 0])

    def context_slots_paged(self, tokens: np.ndarray, lens: np.ndarray,
                            q_start: np.ndarray,
                            stage_tables: Sequence[np.ndarray]) -> np.ndarray:
        """CONTEXT prefill of right-padded chunks `tokens` (m, C) whose
        row-i token j sits at ABSOLUTE position q_start[i] + j — the
        insert-with-nonzero-KV-start path behind warm-prefix serving (only
        a prompt's cold suffix runs here, the shared prefix is already
        resident in pages) and chunked prefill (a long prompt arrives as
        several such calls). Each chunk's K/V scatter into this stage's
        pages through `stage_tables[si]` (m, max_blocks) inside the
        attention layer, and attention reads the prior context back
        through the same table. Returns each row's last-real-token logits
        (m, V) — meaningful once the final chunk of a prompt runs.

        Attention-only stacks (context_mode_supported); q_start == 0 and
        lens == the whole prompt reduces to a one-shot paged prefill of a
        cold request through the context path."""
        assert self.paged_caches is not None, "call init_paged_caches first"
        assert context_mode_supported(self.cfg)
        m, C = tokens.shape
        lens = jnp.asarray(lens, jnp.int32)
        starts = jnp.asarray(q_start, jnp.int32)
        positions = starts[:, None] + jnp.arange(C)[None]
        x = self._embed(jnp.asarray(tokens), {})
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                bt = jnp.asarray(stage_tables[si], jnp.int32)
                x, self.paged_caches[si] = st._context_paged_jit(
                    x, self.paged_caches[si], positions, lens, bt)
        x_last = x[jnp.arange(m), lens - 1][:, None]
        return np.asarray(self._head(x_last)[:, 0])

    def verify_slots_paged(self, tokens: np.ndarray, q_len: np.ndarray,
                           q_start: np.ndarray,
                           stage_tables: Sequence[np.ndarray]) -> np.ndarray:
        """MULTI-TOKEN VERIFICATION over ALL slots (speculative decoding):
        tokens (n_slots, T) is each slot's candidate chunk — the bonus
        token plus its draft proposals, right-padded to the fixed chunk
        width T = spec_k + 1 so the step compiles ONCE — with row i's
        candidate j at absolute position q_start[i] + j (the slot's
        committed KV length). q_len (n_slots,) real candidate counts;
        rows of free / mid-prefill slots carry q_len == 0 and all-null
        tables, scatter into the trash page, and return garbage the
        engine discards — exactly like free slots in the joint decode.

        Returns logits (n_slots, T, V) at EVERY chunk position: position
        j is the target's next-token distribution after consuming
        candidate j, which is what greedy (or rejection-sampling)
        acceptance compares against candidate j + 1. With T == 1 this
        degenerates to the plain joint decode step (one bonus token, no
        proposals). Attention-only stacks (context_mode_supported)."""
        assert self.paged_caches is not None, "call init_paged_caches first"
        assert context_mode_supported(self.cfg)
        n, T = tokens.shape
        lens = jnp.asarray(q_len, jnp.int32)
        starts = jnp.asarray(q_start, jnp.int32)
        positions = starts[:, None] + jnp.arange(T)[None]
        x = self._embed(jnp.asarray(tokens), {})
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                bt = jnp.asarray(stage_tables[si], jnp.int32)
                x, self.paged_caches[si] = st._verify_paged_jit(
                    x, self.paged_caches[si], positions, lens, bt)
        return np.asarray(self._head(x))

    # ---- KV migration (disaggregated prefill/decode) -----------------------
    # The wire format is per-GLOBAL-LAYER so the source and destination
    # pipelines may split their stages differently: stage si's single block
    # table addresses every one of ITS layers' page pools, but each layer
    # owns its own K/V arrays, so regrouping layers across stages is just a
    # different iteration order over the same per-layer payloads.

    def extract_kv_pages(self, stage_blocks: Sequence[Optional[Sequence[int]]]
                         ) -> List[dict]:
        """Gather the page CONTENTS of each stage's block list into host
        arrays: returns ``layer_kv[l] = {"k","v"}`` of shape
        (n_blocks, block_size, kv_heads, head_dim) for every global layer l,
        in layer order. ``stage_blocks[si]`` is the (ordered) physical block
        list of one request on stage si — whole blocks, so a partial tail
        block ships its masked garbage rather than a ragged slice.
        Attention-only stacks (recurrent state has no page identity)."""
        assert self.paged_caches is not None, "no paged caches to extract"
        layer_kv: List[dict] = []
        for si, st in enumerate(self.stages):
            blocks = np.asarray(stage_blocks[si], np.int32)
            for c in self.paged_caches[si]:
                assert "k" in c and "v" in c, \
                    "KV migration covers attention-only stacks"
                lkv = {"k": np.asarray(c["k"][blocks]),
                       "v": np.asarray(c["v"][blocks])}
                # quantized pools ship at wire width + their scale leaves:
                # the int8/fp8 payload is what crosses the link, so the
                # modeled transfer bytes drop with the pool dtype
                for n in ("k_scale", "v_scale"):
                    if n in c:
                        lkv[n] = np.asarray(c[n][blocks])
                layer_kv.append(lkv)
        return layer_kv

    def scatter_kv_pages(self, stage_blocks: Sequence[Optional[Sequence[int]]],
                         layer_kv: Sequence[dict]) -> None:
        """Migrate-in: write per-layer page payloads (extract_kv_pages wire
        format, possibly from a pipeline with a DIFFERENT stage split) into
        this pipeline's pools at each stage's freshly allocated block list.
        A ``None`` entry in ``stage_blocks`` SKIPS that stage (its layers'
        payload slices are discarded) — a cluster prefix fetch lands only
        in the stages that miss locally. Jitted with donation per stage so
        the pools update in place; one compile per distinct payload block
        count."""
        assert self.paged_caches is not None, "call init_paged_caches first"
        li = 0
        for si, st in enumerate(self.stages):
            n_layers = st.hi - st.lo
            if stage_blocks[si] is None:
                li += n_layers
                continue
            payload = [
                {n: jnp.asarray(a) for n, a in layer_kv[li + k].items()}
                for k in range(n_layers)]
            li += n_layers
            with st.mesh:
                self.paged_caches[si] = st._scatter_pages_jit(
                    self.paged_caches[si],
                    jnp.asarray(stage_blocks[si], jnp.int32), payload)
        assert li == len(layer_kv), (li, len(layer_kv))

    # ---- host page tier (device <-> host demotion/promotion) ---------------
    def extract_stage_pages(self, stage_idx: int, blocks: Sequence[int]
                            ) -> List[dict]:
        """Gather stage `stage_idx`'s page contents for `blocks` into host
        arrays — one ``{"k","v"[,"k_scale","v_scale"]}`` pytree per layer
        OF THIS STAGE, at pool precision (quantized pages spill narrow).
        The single-stage slice of ``extract_kv_pages``: host-tier demotion
        is per stage because each stage's pool fills and evicts on its own
        clock."""
        assert self.paged_caches is not None, "no paged caches to extract"
        bl = np.asarray(blocks, np.int32)
        payload: List[dict] = []
        for c in self.paged_caches[stage_idx]:
            assert "k" in c and "v" in c, \
                "host page tier covers attention-only stacks"
            lkv = {"k": np.asarray(c["k"][bl]), "v": np.asarray(c["v"][bl])}
            for n in ("k_scale", "v_scale"):
                if n in c:
                    lkv[n] = np.asarray(c[n][bl])
            payload.append(lkv)
        return payload

    def scatter_stage_pages(self, stage_idx: int, blocks: Sequence[int],
                            payload: Sequence[dict]) -> None:
        """Write ``extract_stage_pages`` payloads back into stage
        `stage_idx`'s pools at `blocks` — host -> device promotion. The
        payload re-lands verbatim (same pool precision it spilled at)."""
        assert self.paged_caches is not None, "call init_paged_caches first"
        st = self.stages[stage_idx]
        jp = [{n: jnp.asarray(a) for n, a in lkv.items()} for lkv in payload]
        with st.mesh:
            self.paged_caches[stage_idx] = st._scatter_pages_jit(
                self.paged_caches[stage_idx],
                jnp.asarray(blocks, jnp.int32), jp)

    def copy_pages(self, stage_idx: int, src_blocks: Sequence[int],
                   dst_blocks: Sequence[int]) -> None:
        """Copy-on-write: duplicate page contents src -> dst in every
        attention layer of stage `stage_idx` (one shared block-id space
        per stage). Host-side bookkeeping (BlockTable.writable) decides
        WHEN; this only moves bytes — donated/jitted per stage, so the
        pools update in place."""
        if not src_blocks:
            return
        st = self.stages[stage_idx]
        with st.mesh:
            self.paged_caches[stage_idx] = st._copy_pages_jit(
                self.paged_caches[stage_idx],
                jnp.asarray(src_blocks, jnp.int32),
                jnp.asarray(dst_blocks, jnp.int32))

    def decode_slots_paged(self, tokens: np.ndarray, positions: np.ndarray,
                           stage_tables: Sequence[np.ndarray]) -> np.ndarray:
        """One decode iteration over ALL slots through the paged caches.
        stage_tables[si]: (n_slots, max_blocks) int32 block table for stage
        si (rows of free slots are all-null and decode into the trash
        page). Returns (n_slots, V)."""
        pos = jnp.asarray(positions, jnp.int32)
        x = self._embed_decode_tokens(jnp.asarray(tokens), pos)
        for si, st in enumerate(self.stages):
            with st.mesh:
                x = jax.device_put(x, _rep(st.mesh))
                bt = jnp.asarray(stage_tables[si], jnp.int32)
                x, self.paged_caches[si] = st._decode_paged_jit(
                    x, self.paged_caches[si], pos, bt)
        return np.asarray(self._head(x)[:, 0])
