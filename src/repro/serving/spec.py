"""Speculative decoding: draft proposals + acceptance, host-side pieces.

Decode is the serving stack's last one-token-per-iteration bottleneck: every
target-model step scans the weights once and commits exactly one token, so a
slow replica's decode latency is pinned to its weight-scan time however much
spare compute its step leaves idle. Speculative decoding (Leviathan et al.;
shipped as a first-class subsystem by vLLM/Aphrodite) converts that spare
per-step compute into MULTIPLE committed tokens: a cheap PROPOSER guesses k
candidate tokens, the target verifies the bonus token plus all k candidates
in ONE multi-token step (ops.paged_verify_attention through
AsymmetricPipeline.verify_slots_paged), and acceptance commits the longest
candidate prefix the target agrees with — between 1 and k + 1 tokens per
target step, never fewer than plain decode.

This module holds the proposers and the acceptance rules; the engine-side
iteration (block growth, COW, joint verify dispatch, page rollback) lives in
``serving.continuous.PagedPipelineBatcher``, the verification kernel path in
``kernels``/``models``, and the acceptance-aware scheduling in
``core.cost_model`` / ``core.genetic``.

Proposers implement one duck-typed protocol, batched per engine iteration:

  propose(items) -> {slot: proposals}
      items: (slot_id, history, k_cap) triples for every slot proposing
      this iteration; `history` is the slot's committed tokens (prompt +
      outputs) plus the bonus token, `k_cap` its per-slot draft budget.
      Returns int32 proposal arrays (possibly shorter than k_cap; slots
      may be absent = no proposal, plain single-token verify).
  commit(slot, n_accepted) -> None
      acceptance outcome, so stateful proposers can keep their per-slot
      state aligned with the committed stream.
  release(slot) -> None
      the slot was freed or preempted; drop its state (the request may
      come back in a different slot).

Two proposers ship:

  * ``NgramProposer`` — prompt-lookup (n-gram) proposing, no extra weights:
    the longest recent n-gram that re-occurred earlier in the slot's
    history proposes its historical continuation. Free to run, surprisingly
    strong on template-heavy / self-repetitive generations.
  * ``DraftModelProposer`` — a small draft model (any attention-only config
    from ``configs/``) decoded greedily k steps ahead per slot, with its
    own per-slot KV rows. Rollback is positional: rejected candidates'
    cache writes sit past the synced length and are overwritten on the
    next proposal, so the draft never needs recomputation on rejection.

The serving engine is greedy end to end (bit-identity is the repo's
correctness bar), so acceptance in the engine is ``greedy_accept``:
committed tokens are exactly the target's argmax chain, making spec-enabled
serving TOKEN-IDENTICAL to plain greedy decode at any acceptance rate. The
standard rejection-sampling rule (which preserves the target DISTRIBUTION
under stochastic sampling) ships as ``rejection_sample_accept`` for
sampling engines and is unit-tested, but is not wired into the greedy loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.obs.trace import NULL_TRACER

ProposeItem = Tuple[int, np.ndarray, int]       # (slot, history, k_cap)


# ---------------------------------------------------------------------------
# Acceptance rules
# ---------------------------------------------------------------------------

def greedy_accept(logits: np.ndarray, bonus: int,
                  drafts: Sequence[int]) -> Tuple[List[int], int]:
    """Greedy acceptance: commit the longest draft prefix that matches the
    target's argmax chain.

    ``logits`` (T, V) are the target's next-token distributions after each
    chunk position (the bonus token at position 0, draft j at position
    j + 1); ``drafts`` holds at most T - 1 proposals. Returns
    ``(commit, a)``: the committed tokens ``[bonus, *accepted drafts]``
    and the accepted draft count ``a`` — ``logits[a]`` is the sampling
    state to carry forward (the distribution after the last committed
    token), whose argmax is the NEXT step's bonus token. By construction
    the committed stream equals plain greedy decode token for token.
    """
    commit = [int(bonus)]
    a = 0
    for j, dj in enumerate(drafts):
        if int(np.argmax(logits[j])) != int(dj):
            break
        commit.append(int(dj))
        a = j + 1
    return commit, a


def rejection_sample_accept(p_target: np.ndarray, p_draft: np.ndarray,
                            drafts: Sequence[int], u: np.ndarray
                            ) -> Tuple[List[int], int]:
    """Rejection-sampling acceptance (Leviathan et al. 2023): accept draft
    j with probability min(1, p_t[d_j] / p_d[d_j]); on the first
    rejection, resample from the residual max(p_t - p_d, 0). Preserves
    the target distribution exactly, whatever the draft proposes.

    p_target (T, V) target probabilities after each chunk position;
    p_draft (len(drafts), V) the draft's probabilities for its proposals;
    u (len(drafts),) uniform variates. Returns (committed tokens AFTER
    the bonus token, accepted draft count) — the caller samples the bonus
    continuation from p_target[a] itself when all drafts are accepted.
    The greedy serving loop does not use this rule (it would break
    bit-identity with greedy decode); sampling engines can.
    """
    commit: List[int] = []
    for j, dj in enumerate(drafts):
        dj = int(dj)
        pt = float(p_target[j, dj])
        pd = float(p_draft[j, dj])
        thr = min(1.0, pt / max(pd, 1e-30))
        if pd <= 0.0 or u[j] < thr:
            commit.append(dj)
            continue
        residual = np.maximum(p_target[j] - p_draft[j], 0.0)
        tot = residual.sum()
        if tot <= 0.0:
            resampled = int(np.argmax(p_target[j]))
        else:
            # conditioned on rejection u[j] is uniform on [thr, 1);
            # renormalize it back to [0, 1) so the inverse-CDF draw from
            # the residual stays exact without a fresh variate
            u_res = (u[j] - thr) / max(1.0 - thr, 1e-30)
            resampled = int(np.argmax(np.cumsum(residual / tot) > u_res))
        commit.append(resampled)
        return commit, j
    return commit, len(commit)


# ---------------------------------------------------------------------------
# Proposers
# ---------------------------------------------------------------------------

class NgramProposer:
    """Prompt-lookup proposing: find the longest n-gram (ngram_max down to
    ngram_min) ending the slot's history that also occurred EARLIER in the
    history, and propose the tokens that followed that earlier occurrence.
    No weights, no state — the history IS the model. Wins big whenever
    generations echo their context (templates, code, summaries, greedy
    loops); proposes nothing when the history never repeats, which costs
    only the unused chunk width."""

    def __init__(self, *, ngram_max: int = 3, ngram_min: int = 1):
        assert 1 <= ngram_min <= ngram_max, (ngram_min, ngram_max)
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min
        self.tracer = NULL_TRACER      # engine shares its tracer on bind

    def propose(self, items: Sequence[ProposeItem]
                ) -> Dict[int, np.ndarray]:
        out: Dict[int, np.ndarray] = {}
        for slot, hist, cap in items:
            if cap <= 0:
                continue
            p = self._lookup(np.asarray(hist), cap)
            if len(p):
                out[slot] = p
        return out

    def _lookup(self, h: np.ndarray, cap: int) -> np.ndarray:
        L = len(h)
        for n in range(min(self.ngram_max, L - 1), self.ngram_min - 1, -1):
            suffix = h[L - n:]
            # windows at p in [0, L-n-1]: every occurrence strictly before
            # the suffix itself (p = L-n), most recent match wins
            win = np.lib.stride_tricks.sliding_window_view(h, n)[:L - n]
            hits = np.flatnonzero((win == suffix).all(axis=1))
            if len(hits):
                p = int(hits[-1])
                return h[p + n:p + n + cap].astype(np.int32)
        return np.zeros(0, np.int32)

    def commit(self, slot: int, n_accepted: int) -> None:
        pass

    def release(self, slot: int) -> None:
        pass


class DraftModelProposer:
    """A small draft model decoded greedily ``k_cap`` steps ahead per slot.

    The draft keeps ONE monolithic cache pool whose batch rows mirror the
    engine's slots (contiguous layout — the draft is tiny, reservation
    waste is noise). Per slot it tracks ``_pos[slot]``: how many history
    tokens its cache currently holds. Proposing feeds the bonus token at
    position len(history) - 1 and argmax-continues k steps, caching as it
    goes; ``commit`` extends the synced length by the accepted count, so
    accepted candidates' K/V (already written during proposing) are kept
    and rejected candidates' writes sit PAST the synced length — masked by
    kv_len and overwritten by the next proposal, the same positional
    rollback the target's paged verification uses. A slot whose cache
    drifts from its history (fresh request, preemption recompute,
    migration landing) is re-prefilled from scratch; ``release`` just
    zeroes the synced length.

    Attention-only draft configs (same predicate as the verification
    path): recurrent draft state is a running summary that cannot rewind
    past a rejected candidate.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int,
                 max_len: int, pad_id: int = 0):
        import jax

        from repro.models import model as M
        from repro.serving.pipeline import context_mode_supported
        assert context_mode_supported(cfg), \
            "draft models must be attention-only text decoders " \
            "(recurrent draft state cannot be rolled back on rejection)"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self._M = M
        self._jnp_asarray = jax.numpy.asarray
        self.cache = M.init_cache(cfg, n_slots, max_len)
        # tokens of each slot's history currently cached (0 = unsynced)
        self._pos = np.zeros(n_slots, np.int64)
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        self._prefill = jax.jit(
            lambda p, toks, lens, c: M.prefill(cfg, p, {"tokens": toks}, c,
                                               lens=lens))
        self.draft_steps = 0           # draft forward passes run (profiling)
        self.tracer = NULL_TRACER      # engine shares its tracer on bind

    # ---- sync: (re)prefill slots whose cache doesn't hold history[:-1] ----
    def _sync(self, items: Sequence[ProposeItem]) -> None:
        need = [(slot, h) for slot, h, _ in items
                if self._pos[slot] != len(h) - 1]
        if not need:
            return
        m = len(need)
        lens = np.asarray([len(h) - 1 for _, h in need], np.int32)
        assert int(lens.max()) < self.max_len, "history exceeds draft cache"
        # same compile-shape bucketing as the engine's insert path
        P = min(-(-int(lens.max()) // 16) * 16, self.max_len - 1)
        m_pad = min(1 << (m - 1).bit_length(), self.n_slots)
        m_pad = max(m_pad, m)
        toks = np.full((m_pad, P), self.pad_id, np.int32)
        plens = np.ones((m_pad,), np.int32)
        plens[:m] = lens
        for i, (_, h) in enumerate(need):
            toks[i, :lens[i]] = h[:-1]
        import jax
        scratch = self._M.init_cache(self.cfg, m_pad, self.max_len)
        _, scratch = self._prefill(self.params, self._jnp_asarray(toks),
                                   self._jnp_asarray(plens), scratch)
        rows = jax.tree.map(lambda l: l[:, :m], scratch)
        self.cache = self._M.scatter_cache_rows(
            self.cache, rows, [slot for slot, _ in need], batch_axis=1)
        for slot, h in need:
            self._pos[slot] = len(h) - 1

    def propose(self, items: Sequence[ProposeItem]
                ) -> Dict[int, np.ndarray]:
        act = [(slot, h, cap) for slot, h, cap in items if cap > 0]
        if not act:
            return {}
        synced = sum(1 for slot, h, _ in act
                     if self._pos[slot] != len(h) - 1)
        self._sync(act)
        # steps 0..cap-1 produce the proposals; one EXTRA step per slot
        # feeds its final proposal back purely to write that candidate's
        # K/V (its logits are discarded) — without it a fully-accepted
        # round would leave the cache one position short of what commit()
        # marks synced, silently degrading every later proposal
        steps = max(cap for _, _, cap in act) + 1
        # rows not proposing this step PARK at the last cache position:
        # their write lands in a slot row's never-read tail (the target
        # caps committed positions at max_len - 2, so the draft never
        # legitimately writes max_len - 1) and their logits are discarded
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.full((self.n_slots,), self.max_len - 1, np.int64)
        for slot, h, _ in act:
            toks[slot] = int(h[-1])
            pos[slot] = len(h) - 1
        out: Dict[int, List[int]] = {slot: [] for slot, _, _ in act}
        for step in range(steps):
            logits, self.cache = self._decode(
                self.params, self._jnp_asarray(toks), self.cache,
                self._jnp_asarray(pos))
            self.draft_steps += 1
            logits = np.asarray(logits)
            for slot, h, cap in act:
                if step < cap:
                    nxt = int(logits[slot].argmax())
                    out[slot].append(nxt)
                    toks[slot] = nxt
                    pos[slot] += 1
                elif step == cap:
                    # the final proposal's K/V was written by the decode
                    # call just above; park from here on
                    pos[slot] = self.max_len - 1
        for slot, h, _ in act:
            # cache now holds the history through the bonus token; the
            # proposals' K/V past it become valid only via commit()
            self._pos[slot] = len(h)
        if self.tracer.enabled:
            self.tracer.instant("spec_draft", steps=steps, slots=len(act),
                                synced=synced)
        return {slot: np.asarray(v, np.int32) for slot, v in out.items()}

    def commit(self, slot: int, n_accepted: int) -> None:
        """Accepted candidates' K/V were written during proposing; extend
        the synced length over exactly those positions."""
        self._pos[slot] += n_accepted

    def release(self, slot: int) -> None:
        self._pos[slot] = 0


# ---------------------------------------------------------------------------
# Config / builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding knobs, carried from the launcher through
    Router/InferenceEngine to each replica's engine.

    k:        draft tokens proposed per target step (chunk width k + 1).
              The scheduler's acceptance-aware search can override this
              PER REPLICA (slow replicas speculate deeper) via
              ``Router(spec_ks=...)``.
    proposer: "ngram" (prompt lookup, no weights) or "draft" (small draft
              model decoded k ahead; requires ``draft_cfg``).
    draft_token_cost: virtual-clock cost of ONE draft proposal as a
              fraction of a target iteration (0 = free proposals). Lets
              simulated latencies charge the draft overhead the
              acceptance-aware cost model reasons about.
    """
    k: int = 4
    proposer: str = "ngram"
    ngram_max: int = 3
    ngram_min: int = 1
    draft_cfg: Optional[ModelConfig] = None
    draft_params: Optional[dict] = None
    draft_seed: int = 0
    draft_token_cost: float = 0.0

    def __post_init__(self):
        assert self.k >= 1, self.k
        assert self.proposer in ("ngram", "draft"), self.proposer
        if self.proposer == "draft":
            assert self.draft_cfg is not None, \
                "proposer='draft' needs a draft_cfg"

    def build(self, *, n_slots: int, max_len: int, vocab_size: int,
              pad_id: int = 0):
        """Instantiate this config's proposer for one replica engine."""
        if self.proposer == "ngram":
            return NgramProposer(ngram_max=self.ngram_max,
                                 ngram_min=self.ngram_min)
        assert self.draft_cfg.vocab_size == vocab_size, \
            (self.draft_cfg.vocab_size, vocab_size,
             "draft and target must share a vocabulary")
        params = self.draft_params
        if params is None:
            import jax

            from repro.models import model as M
            params = M.init_params(self.draft_cfg,
                                   jax.random.PRNGKey(self.draft_seed))
        return DraftModelProposer(self.draft_cfg, params, n_slots=n_slots,
                                  max_len=max_len, pad_id=pad_id)
