"""ServingConfig: the one typed surface for every serving knob.

``launch/serve.py`` used to parse ~34 argparse flags into an ad-hoc
namespace and forward them as three separate kwarg piles (scheduler,
Router, InferenceEngine); benches and smokes each re-invented subsets of
that plumbing. ``ServingConfig`` collapses the surface into a single
dataclass that owns:

  * the argparse schema — ``add_args``/``from_args`` generate the CLI
    from field metadata, so a flag exists exactly once;
  * serialization — ``to_args`` round-trips back to an argv list
    (``from_args(parse(to_args(cfg))) == cfg``), ``to_json``/``from_json``
    persist configs into results files and relaunch them;
  * feature gating — ``normalized()`` applies the layout-compatibility
    rules (disaggregation/speculation/quantized-KV/host-tier need the
    paged layout) in ONE place, warning and downgrading exactly like the
    old inline checks;
  * derived planning inputs — ``task()``, ``schedule_kwargs()``,
    ``workload()``, ``max_len()``, ``guard_layers()``.

Engines consume it through ``InferenceEngine.from_config(cfg, plan,
serving)`` together with a ``core.plan.DeploymentPlan`` — the scheduler's
verdict (replica layouts, roles, spec depths, KV precisions, host-tier
split) — so the config says HOW to serve and the plan says WHERE.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import cluster as cl
from repro.core import cost_model as cm

CLUSTERS = {
    "case_study": cl.case_study_cluster,
    "half_price": cl.hetero_half_price,
    "full_price": cl.hetero_full_price,
    "homogeneous": cl.homogeneous_a100,
    "tpu_mixed": cl.tpu_mixed_slices,
}


def _f(default, help="", choices=None):
    meta: Dict[str, Any] = {"help": help}
    if choices is not None:
        meta["choices"] = choices
    return dataclasses.field(default=default, metadata=meta)


@dataclasses.dataclass
class ServingConfig:
    """Every CLI-reachable serving knob, typed, in declaration order."""

    # ---- model / pool / workload shape ---------------------------------
    arch: str = _f("h2o-danube-1.8b", "model architecture from configs/")
    reduced: bool = _f(False, "serve the reduced variant (CPU-sized) of "
                              "the scheduled architecture")
    cluster: str = _f("case_study", "GPU pool to schedule on",
                      choices=tuple(CLUSTERS))
    rate: float = _f(2.0, "Poisson arrival rate (req/s)")
    duration: float = _f(5.0, "workload duration (s)")
    deadline: float = _f(30.0, "per-request SLO deadline (s)")
    out_len: int = _f(8, "decode tokens per request")
    prompt_len: int = _f(24, "prompt tokens per request")
    search_iters: int = _f(10, "genetic search iterations")
    seed: int = _f(0, "workload / search / params seed")
    # ---- engine policy and KV layout -----------------------------------
    policy: str = _f("continuous", "iteration-level slot batching vs the "
                                   "paper's static whole-batch engine",
                     choices=("continuous", "static"))
    cache_layout: str = _f("contiguous", "per-slot max_len cache rows vs "
                                         "block-paged KV with per-stage "
                                         "pools (docs/memory.md)",
                           choices=("contiguous", "paged"))
    block_size: int = _f(16, "KV page size in tokens (paged layout)")
    prefix_caching: bool = _f(False, "alias block-aligned shared prompt "
                                     "prefixes copy-on-write and prefill "
                                     "only cold suffixes (paged layout "
                                     "only)")
    prefill_chunk: int = _f(0, "split prefills longer than this many "
                               "tokens into chunks interleaved with "
                               "decode iterations (0 = one-shot; paged "
                               "layout only)")
    prefix_hit_rate: float = _f(0.0, "expected fraction of prompt tokens "
                                     "served from the prefix cache; the "
                                     "scheduler plans KV capacity against "
                                     "the deduplicated demand")
    shared_prefix: int = _f(0, "generate prompts with this many shared "
                               "system-prompt tokens (exercises the "
                               "prefix cache)")
    # ---- host tier / cluster-wide prefix directory ---------------------
    host_mem_gb: float = _f(0.0, "pool-wide host-memory budget for the "
                                 "page tier (GB), split across replicas "
                                 "by KV-capacity deficit (paged + "
                                 "--prefix-caching)")
    host_swap_gbps: float = _f(0.0, "host<->device swap (and peer-fetch) "
                                    "bandwidth in Gbit/s the scheduler "
                                    "prices tiered hits at (0 = ideal "
                                    "free swap)")
    host_swap_cost: float = _f(0.0, "serving-clock cost of swapping one "
                                    "block between tiers, as a fraction "
                                    "of one iteration (virtual-clock "
                                    "replays only)")
    cluster_prefix: bool = _f(False, "join every replica into a shared "
                                     "prefix directory; peer prefixes "
                                     "fetch over the KV link and the "
                                     "router scores admission by "
                                     "resident prefix")
    prefix_route_weight: float = _f(0.25, "router weight of one resident "
                                          "prefix block against queue "
                                          "depth (0 = pure least-loaded)")
    route_seed: Optional[int] = _f(None, "seed the router's dispatch "
                                         "tiebreaks instead of the "
                                         "deterministic lowest-replica-id "
                                         "order")
    prefix_working_set: int = _f(0, "hot shared-prefix working set in "
                                    "TOKENS: the scheduler derives the "
                                    "achievable per-replica hit rate "
                                    "from tiered residency instead of "
                                    "trusting --prefix-hit-rate verbatim")
    # ---- disaggregated prefill/decode ----------------------------------
    disaggregate: bool = _f(False, "split prefill and decode across "
                                   "replicas; the scheduler also searches "
                                   "the role split (paged layout, >= 2 "
                                   "replicas)")
    kv_link_gbps: float = _f(0.0, "flat bandwidth of the prefill->decode "
                                  "KV link in Gbit/s (0 = per-pair costs "
                                  "from the cluster's comm matrices)")
    # ---- speculative decoding ------------------------------------------
    spec_decode: bool = _f(False, "speculative decoding: propose up to "
                                  "--spec-k tokens per slot per iteration "
                                  "and commit the verified prefix in one "
                                  "multi-token target step (paged layout "
                                  "+ attention-only stacks)")
    draft_model: str = _f("", "draft architecture from configs/ for the "
                              "proposer (empty = weight-free n-gram / "
                              "prompt-lookup proposing)")
    spec_k: int = _f(4, "draft tokens proposed per target step; the "
                        "scheduler's acceptance-aware search may deepen "
                        "or shallow this per replica")
    spec_alpha: float = _f(0.7, "expected per-token draft acceptance rate "
                                "the scheduler plans decode cost per "
                                "COMMITTED token with")
    spec_draft_cost: float = _f(0.0, "modeled cost of one draft step "
                                     "(absolute seconds for the "
                                     "scheduler; per proposed token as an "
                                     "iteration fraction in virtual-clock "
                                     "replays)")
    # ---- KV precision / sanitizer --------------------------------------
    kv_dtype: str = _f("auto", "paged KV pool storage precision; 'auto' "
                               "keeps the model default, 'search' lets "
                               "the scheduler pick per replica",
                       choices=("auto", "search", "fp32", "bf16", "int8",
                                "fp8"))
    kv_guard_layers: int = _f(0, "pin this many layers at EACH END of the "
                                 "stack at model precision under a "
                                 "quantized --kv-dtype")
    kvsan: bool = _f(False, "serve under the KVSAN page-lifecycle "
                            "sanitizer; leaks surface as "
                            "ServeStats.kvsan_leaks (paged layout)")

    # ---- observability (repro.obs) --------------------------------------
    trace_out: str = _f("", "write a Chrome-trace/Perfetto JSON of the "
                            "serve's lifecycle spans to this path "
                            "(empty = tracing off, zero overhead)")
    metrics_out: str = _f("", "write the serve's metrics registry "
                              "(counters/gauges/histograms) as JSONL to "
                              "this path")
    calibrate: bool = _f(False, "record predicted phase costs alongside "
                                "observed span durations and print the "
                                "predicted-vs-observed calibration table")

    # ---- argparse / serialization --------------------------------------

    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
        """Generate the CLI from the field schema: one flag per field,
        ``--kebab-case`` names, bools as store_true."""
        for f in dataclasses.fields(cls):
            flag = "--" + f.name.replace("_", "-")
            help_ = f.metadata.get("help", "")
            choices = f.metadata.get("choices")
            if f.type == "bool" or isinstance(f.default, bool):
                ap.add_argument(flag, action="store_true",
                                default=f.default, help=help_)
            elif f.name == "route_seed":
                ap.add_argument(flag, type=int, default=None, help=help_)
            else:
                ap.add_argument(flag, type=type(f.default),
                                default=f.default, choices=choices,
                                help=help_)
        return ap

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ServingConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in vars(args).items() if k in names})

    @classmethod
    def parse(cls, argv: Optional[Sequence[str]] = None) -> "ServingConfig":
        ap = argparse.ArgumentParser()
        cls.add_args(ap)
        return cls.from_args(ap.parse_args(argv))

    def to_args(self) -> List[str]:
        """Back to an argv list; defaults are omitted, so
        ``from_args(parse(to_args(cfg))) == cfg``."""
        out: List[str] = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v == f.default:
                continue
            flag = "--" + f.name.replace("_", "-")
            if isinstance(v, bool):
                out.append(flag)
            else:
                out.extend([flag, str(v)])
        return out

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ServingConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in json.loads(s).items() if k in names})

    # ---- feature gating -------------------------------------------------

    def normalized(self) -> "ServingConfig":
        """Apply the layout-compatibility rules, warning on each downgrade
        (same behavior the launch driver used to inline). Idempotent:
        a consistent config comes back unchanged."""
        c = dataclasses.replace(self)
        if c.prefix_hit_rate and c.cache_layout != "paged":
            warnings.warn(
                "--prefix-hit-rate only affects capacity planning with "
                "--cache-layout paged (contiguous replicas are simulated "
                "unbounded); ignoring it", stacklevel=2)
            c.prefix_hit_rate = 0.0
        if c.disaggregate and c.cache_layout != "paged":
            warnings.warn(
                "--disaggregate needs --cache-layout paged (the KV "
                "handoff is a page transfer); serving colocated",
                stacklevel=2)
            c.disaggregate = False
        if c.spec_decode and c.cache_layout != "paged":
            warnings.warn(
                "--spec-decode needs --cache-layout paged (multi-token "
                "verification runs through the paged context path); "
                "serving without it", stacklevel=2)
            c.spec_decode = False
        if c.kv_dtype != "auto" and c.cache_layout != "paged":
            warnings.warn(
                "--kv-dtype needs --cache-layout paged (precision is a "
                "page-pool layout); serving at model precision",
                stacklevel=2)
            c.kv_dtype = "auto"
        if (c.host_mem_gb > 0 or c.cluster_prefix) \
                and not (c.cache_layout == "paged" and c.prefix_caching):
            warnings.warn(
                "--host-mem-gb/--cluster-prefix need --cache-layout "
                "paged with --prefix-caching (tiers and the directory "
                "hold prefix blocks); serving without them", stacklevel=2)
            c.host_mem_gb = 0.0
            c.cluster_prefix = False
        return c

    # ---- derived planning inputs ----------------------------------------

    def pool(self):
        return CLUSTERS[self.cluster]()

    def fixed_kv_dtype(self) -> Optional[str]:
        """The one pool-wide precision, or None when 'auto' (model
        default) / 'search' (per-replica scheduler choice)."""
        return None if self.kv_dtype in ("auto", "search") else self.kv_dtype

    def task(self) -> cm.Task:
        # the scheduler must plan for the prompts the engine will actually
        # serve: shared_prefix prepends that many system-prompt tokens
        return cm.Task(batch=1, s_in=self.prompt_len + self.shared_prefix,
                       s_out=self.out_len)

    def schedule_kwargs(self) -> Dict[str, Any]:
        """Kwargs for ``core.scheduler.schedule`` beyond (pool, arch,
        task)."""
        return dict(
            deadline=self.deadline, rate=self.rate,
            iters=self.search_iters, seed=self.seed,
            kv_block_size=(self.block_size
                           if self.cache_layout == "paged" else None),
            prefix_hit_rate=self.prefix_hit_rate,
            disaggregate=self.disaggregate,
            kv_link_gbps=self.kv_link_gbps,
            spec_decode=self.spec_decode,
            spec_alpha=self.spec_alpha,
            spec_draft_cost=self.spec_draft_cost,
            max_spec_k=max(self.spec_k, 1),
            kv_dtype=self.fixed_kv_dtype(),
            kv_dtype_search=(self.kv_dtype == "search"),
            host_tier_bytes=self.host_mem_gb * 1e9,
            host_swap_gbps=self.host_swap_gbps,
            prefix_working_set=self.prefix_working_set,
            cluster_prefix=self.cluster_prefix)

    def max_len(self) -> int:
        """Cache capacity per slot: prompt + jitter headroom + decode
        budget, rounded up to whole pages under the paged layout."""
        n = self.prompt_len + self.shared_prefix + 8 + self.out_len
        if self.cache_layout == "paged":
            n += (-n) % self.block_size
        return n

    def guard_layers(self, num_layers: int) -> List[int]:
        """Global layer ids pinned at model precision: the first/last
        ``kv_guard_layers`` of the SERVED stack."""
        if self.kv_guard_layers <= 0:
            return []
        n = min(self.kv_guard_layers, num_layers // 2)
        return list(range(n)) + list(range(num_layers - n, num_layers))

    def workload(self, vocab_size: int):
        """The synthetic request stream this config describes."""
        from repro.serving.request import (shared_prefix_workload,
                                           synth_workload)
        if self.shared_prefix:
            return shared_prefix_workload(
                rate=self.rate, duration=self.duration, vocab=vocab_size,
                shared_len=self.shared_prefix, unique_len=self.prompt_len,
                unique_jitter=4, out_len=self.out_len, seed=self.seed)
        return synth_workload(rate=self.rate, duration=self.duration,
                              vocab=vocab_size, prompt_len=self.prompt_len,
                              prompt_jitter=4, out_len=self.out_len,
                              seed=self.seed)
