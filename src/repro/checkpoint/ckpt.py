"""Sharding-aware npz checkpointing (no orbax dependency).

Layout: <dir>/step_<n>/params.npz + opt_state.npz + meta.json. Pytrees are
flattened with '/'-joined key paths; arrays are gathered to host (fine at
demo scale; a real pod deployment would write per-host shards -- the format
reserves a `shard` field for that)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, step: int, params, opt_state: Any = None,
         extra: Optional[dict] = None) -> str:
    d = os.path.join(path, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    np.savez(os.path.join(d, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(d, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    return d


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for n in os.listdir(path)
             if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None


def restore(path: str, step: int, params_like, opt_like=None
            ) -> Tuple[Any, Any, dict]:
    """Restores into the structure of `params_like` (shape/dtype checked)."""
    d = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(d, "params.npz"))

    def unflatten(like, blob):
        flat = _flatten(like)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        keys = list(flat.keys())
        assert len(keys) == len(leaves)
        out = []
        for key, leaf in zip(keys, leaves):
            arr = blob[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            out.append(arr.astype(leaf.dtype))
        return treedef.unflatten(out)

    params = unflatten(params_like, data)
    opt = None
    if opt_like is not None:
        opt = unflatten(opt_like, np.load(os.path.join(d, "opt_state.npz")))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return params, opt, meta
