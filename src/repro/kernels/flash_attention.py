"""Pallas TPU flash-attention (prefill/training forward).

Tiling: grid (batch, q_head, q_blocks, kv_blocks); the kv axis is the
innermost (sequential on TPU), so the online-softmax state (m, l, acc) lives
in VMEM scratch carried across kv steps and the output tile is emitted on the
last kv step. Block shapes are MXU-friendly (q_block x head_dim and
kv_block x head_dim tiles, multiples of 128 for full-size configs). GQA maps
q-head h to kv-head h // (hq // hkv) in the k/v BlockSpec index maps.

Masking (causal / sliding window / kv_len / kv_start) is applied with
broadcasted iotas inside the kernel; fully-masked tiles short-circuit to
zero contribution. Validated against ref.attention_ref in interpret mode
(CPU) by tests/test_kernels.py; real-TPU execution uses the same code path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, start_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, causal, window, nk,
            q_block, kv_block, use_len, use_start):
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = iq * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    kpos = ik * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if use_len:
        mask &= kpos < len_ref[0]
    if use_start:
        mask &= kpos >= start_ref[0]

    q = q_ref[0, 0].astype(jnp.float32)                 # (qblk, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (kvblk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0, kv_len=None,
                           kv_start=None, q_block=512, kv_block=512,
                           scale=None, interpret=False):
    """q (b,sq,hq,d); k,v (b,skv,hkv,d) -> (b,sq,hq,d)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0 and skv % kv_block == 0, (sq, skv)
    nq, nk = sq // q_block, skv // kv_block
    if window >= skv:
        window = 0

    qt = jnp.moveaxis(q, 2, 1)                          # (b,hq,sq,d)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    use_len = kv_len is not None
    use_start = kv_start is not None
    lenb = kv_len if use_len else jnp.zeros((b,), jnp.int32)
    startb = kv_start if use_start else jnp.zeros((b,), jnp.int32)

    grid = (b, hq, nq, nk)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, nk=nk,
        q_block=q_block, kv_block=kv_block, use_len=use_len,
        use_start=use_start)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1,), lambda ib, ih, iq, ik: (ib,)),
            pl.BlockSpec((1,), lambda ib, ih, iq, ik: (ib,)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, d), jnp.float32),      # acc
            pltpu.VMEM((q_block,), jnp.float32),        # m
            pltpu.VMEM((q_block,), jnp.float32),        # l
        ],
        interpret=interpret,
    )(qt, kt, vt, lenb, startb)
    return jnp.moveaxis(out, 1, 2)
