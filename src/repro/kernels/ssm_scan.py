"""Pallas TPU chunked selective scan (Mamba S6).

Tiling: grid (batch, d_inner_blocks, chunks); the chunk axis is innermost
(sequential on TPU) so the recurrent state h (din_block, d_state) lives in
VMEM scratch and is carried across chunk steps — the TPU-native adaptation
of the CUDA selective-scan: instead of warp-level parallel prefix sums, each
core streams (chunk x din_block) input tiles from HBM and steps the
recurrence over the chunk with the state resident in VMEM (HBM -> VMEM ->
VREG hierarchy; the time loop is a fori_loop over VREG-resident rows).

y[t] = C[t] . h[t] + D * x[t],  h[t] = exp(dt[t] A) h[t-1] + dt[t] x[t] B[t]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref, y_ref,
            hout_ref, h_ref, *, chunk, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)      # (dblk, ds)

    A = A_ref[...].astype(jnp.float32)                  # (dblk, ds)
    D = D_ref[...].astype(jnp.float32)                  # (dblk,)

    def step(t, carry):
        h = carry
        xt = x_ref[0, t].astype(jnp.float32)            # (dblk,)
        dtt = dt_ref[0, t].astype(jnp.float32)          # (dblk,)
        Bt = B_ref[0, t].astype(jnp.float32)            # (ds,)
        Ct = C_ref[0, t].astype(jnp.float32)            # (ds,)
        dA = jnp.exp(dtt[:, None] * A)                  # (dblk, ds)
        h = dA * h + (dtt * xt)[:, None] * Bt[None, :]
        y = (h * Ct[None, :]).sum(axis=1) + D * xt      # (dblk,)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == nc - 1)
    def _emit():
        hout_ref[0] = h.astype(hout_ref.dtype)


def ssm_scan_pallas(x, dt, A, B, C, D, *, h0=None, chunk=128,
                    d_block=None, interpret=False):
    """x, dt (b,s,din); A (din,ds); B,C (b,s,ds); D (din,).
    Returns (y (b,s,din), h (b,din,ds))."""
    b, s, din = x.shape
    ds = A.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    d_block = d_block or min(din, 512)
    assert din % d_block == 0
    ndb = din // d_block
    if h0 is None:
        h0 = jnp.zeros((b, din, ds), jnp.float32)

    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, hout = pl.pallas_call(
        kern,
        grid=(b, ndb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block),
                         lambda ib, idb, ic: (ib, ic, idb)),   # x
            pl.BlockSpec((1, chunk, d_block),
                         lambda ib, idb, ic: (ib, ic, idb)),   # dt
            pl.BlockSpec((d_block, ds), lambda ib, idb, ic: (idb, 0)),  # A
            pl.BlockSpec((1, chunk, ds), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, idb, ic: (ib, ic, 0)),
            pl.BlockSpec((d_block,), lambda ib, idb, ic: (idb,)),      # D
            pl.BlockSpec((1, d_block, ds),
                         lambda ib, idb, ic: (ib, idb, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block),
                         lambda ib, idb, ic: (ib, ic, idb)),   # y
            pl.BlockSpec((1, d_block, ds),
                         lambda ib, idb, ic: (ib, idb, 0)),    # h final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, din), x.dtype),
            jax.ShapeDtypeStruct((b, din, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, h0)
    return y, hout
