"""Pallas TPU flash-decode: one query token against a long KV cache.

Tiling: grid (batch, q_head, kv_blocks); kv innermost/sequential with
online-softmax scratch in VMEM, like flash_attention but with q_len == 1 —
the kernel keeps the single query row resident in VREGs while streaming
kv_block x head_dim tiles from the cache (the HBM-bandwidth-bound regime of
decode). Out-of-range cache slots (kv_len / kv_start) are masked via iota.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, start_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, nk, kv_block, use_len,
            use_start):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = ik * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (1, kv_block), 1)
    mask = jnp.ones((1, kv_block), jnp.bool_)
    if use_len:
        mask &= kpos < len_ref[0]
    if use_start:
        mask &= kpos >= start_ref[0]

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (kvblk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, *, kv_len=None, kv_start=None,
                            kv_block=512, scale=None, interpret=False):
    """q (b,1,hq,d); k,v (b,S,hkv,d) -> (b,1,hq,d)."""
    b, one, hq, d = q.shape
    assert one == 1
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_block = min(kv_block, skv)
    pad = (-skv) % kv_block
    if pad:
        # ragged final block: pad the cache to a whole block and mask the
        # tail via kv_len (positions >= the true skv are never attended)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tail = jnp.full((b,), skv, jnp.int32)
        kv_len = tail if kv_len is None else jnp.minimum(
            jnp.asarray(kv_len, jnp.int32), tail)
        skv += pad
    nk = skv // kv_block

    qt = jnp.moveaxis(q, 2, 1)                          # (b,hq,1,d)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    use_len = kv_len is not None
    use_start = kv_start is not None
    lenb = kv_len if use_len else jnp.zeros((b,), jnp.int32)
    startb = kv_start if use_start else jnp.zeros((b,), jnp.int32)

    kern = functools.partial(_kernel, scale=scale, nk=nk, kv_block=kv_block,
                             use_len=use_len, use_start=use_start)
    out = pl.pallas_call(
        kern,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda ib, ih, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda ib, ih, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,)),
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, lenb, startb)
    return jnp.moveaxis(out, 1, 2)
