"""Pallas TPU paged flash-decode: one query token against a BLOCK-PAGED KV
cache, gathered through a per-sequence block table.

The cache is a pool of physical pages ``k_pages/v_pages
(n_blocks, block_size, h_kv, d)`` shared by every in-flight sequence; a
sequence's logical KV positions [0, kv_len) live at
``pages[table[p // block_size], p % block_size]``. The grid is
(batch, q_head, logical_blocks) with the logical-block axis innermost and
sequential, carrying online-softmax scratch in VMEM exactly like the
contiguous flash-decode kernel — the only difference is WHERE each KV tile
comes from: the block table is a scalar-prefetch operand
(PrefetchScalarGridSpec) so the index map can route each grid step's DMA to
the right physical page before the kernel body runs.

Ragged tails need no special casing: the final logical block is simply
masked by kv_len, and unallocated table entries point at the reserved null
page (never unmasked).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, nb, block_size):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = ik * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    mask = kpos < len_ref[ib]

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, d)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (block_size, d)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables, *,
                                  kv_len=None, scale=None, interpret=False):
    """q (b,1,hq,d); k_pages,v_pages (n_blocks,block_size,hkv,d);
    block_tables (b,max_blocks) int32; kv_len (b,) valid lengths
    (default: every table slot full). Returns (b,1,hq,d)."""
    b, one, hq, d = q.shape
    assert one == 1
    n_blocks, block_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = jnp.full((b,), nb * block_size, jnp.int32)
    tbl = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.asarray(kv_len, jnp.int32)

    kern = functools.partial(_kernel, scale=scale, nb=nb,
                             block_size=block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda ib, ih, ik, tbl, lens: (ib, 0, ih, 0)),
            pl.BlockSpec((1, block_size, 1, d),
                         lambda ib, ih, ik, tbl, lens:
                         (tbl[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, block_size, 1, d),
                         lambda ib, ih, ik, tbl, lens:
                         (tbl[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda ib, ih, ik, tbl, lens: (ib, 0, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, d), q.dtype),
        interpret=interpret,
    )(tbl, lens, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# Quantized page pools (int8 / fp8 payloads + per-token-per-head f32 scales,
# models/quant.py): the SAME grid and DMA routing, with two extra tensor
# operands — the scale pools (n_blocks, block_size, h_kv) — riding the same
# scalar-prefetch block-table index map as the pages they describe. Dequant
# is fused in-register: each tile's payload is widened to f32 and multiplied
# by its scale column right before the online-softmax dot, so a full-width
# page is never materialized in HBM or VMEM.
# ---------------------------------------------------------------------------

def _kernel_quant(tbl_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, scale, nb, block_size):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = ik * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    mask = kpos < len_ref[ib]

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, d)
    k = k_ref[0, :, 0].astype(jnp.float32) \
        * ks_ref[0, :, 0].astype(jnp.float32)[:, None]  # (block_size, d)
    v = v_ref[0, :, 0].astype(jnp.float32) \
        * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_quant_pallas(q, k_pages, v_pages, k_scale,
                                        v_scale, block_tables, *,
                                        kv_len=None, scale=None,
                                        interpret=False):
    """Quantized-pool decode: k_pages/v_pages (n_blocks,block_size,hkv,d)
    int8/fp8 payloads, k_scale/v_scale (n_blocks,block_size,hkv) f32.
    Otherwise identical to paged_decode_attention_pallas."""
    b, one, hq, d = q.shape
    assert one == 1
    n_blocks, block_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = jnp.full((b,), nb * block_size, jnp.int32)
    tbl = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.asarray(kv_len, jnp.int32)

    page_spec = pl.BlockSpec((1, block_size, 1, d),
                             lambda ib, ih, ik, tbl, lens:
                             (tbl[ib, ik], 0, ih // g, 0))
    scale_spec = pl.BlockSpec((1, block_size, 1),
                              lambda ib, ih, ik, tbl, lens:
                              (tbl[ib, ik], 0, ih // g))
    kern = functools.partial(_kernel_quant, scale=scale, nb=nb,
                             block_size=block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda ib, ih, ik, tbl, lens: (ib, 0, ih, 0)),
            page_spec,
            page_spec,
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda ib, ih, ik, tbl, lens: (ib, 0, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, d), q.dtype),
        interpret=interpret,
    )(tbl, lens, q, k_pages, v_pages, k_scale, v_scale)


# ---------------------------------------------------------------------------
# Paged CONTEXT prefill: a chunk of C new tokens against the paged cache
# (prior pages + the chunk's own K/V, already scattered in) — the warm-prefix
# and chunked-prefill kernel. Identical grid/DMA structure to the decode
# kernel above; the q axis just widens from 1 to C and the mask gains the
# causal triangle (kpos <= q_start + row).
# ---------------------------------------------------------------------------

def _ctx_kernel(tbl_ref, start_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref, *, scale, nb, block_size, C):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = ik * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (C, block_size), 1)
    qpos = start_ref[ib] + jax.lax.broadcasted_iota(
        jnp.int32, (C, block_size), 0)
    mask = (kpos <= qpos) & (kpos < len_ref[ib])

    q = q_ref[0, :, 0].astype(jnp.float32)              # (C, d)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (block_size, d)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[:, None]
        o = jnp.where(m_ref[...][:, None] <= NEG_INF / 2, 0.0, o)
        o_ref[0, :, 0] = o.astype(o_ref.dtype)


def paged_context_attention_pallas(q, k_pages, v_pages, block_tables, *,
                                   q_start, kv_len, scale=None,
                                   interpret=False):
    """q (b,C,hq,d) — chunk of new tokens, row i's token j at absolute
    position q_start[i] + j; k_pages/v_pages (n_blocks,block_size,hkv,d)
    already hold the chunk's K/V at [q_start, kv_len); block_tables
    (b,max_blocks) int32; q_start,kv_len (b,). Returns (b,C,hq,d)."""
    b, C, hq, d = q.shape
    n_blocks, block_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    tbl = jnp.asarray(block_tables, jnp.int32)
    starts = jnp.asarray(q_start, jnp.int32)
    lens = jnp.asarray(kv_len, jnp.int32)

    kern = functools.partial(_ctx_kernel, scale=scale, nb=nb,
                             block_size=block_size, C=C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, C, 1, d),
                         lambda ib, ih, ik, tbl, st, ln: (ib, 0, ih, 0)),
            pl.BlockSpec((1, block_size, 1, d),
                         lambda ib, ih, ik, tbl, st, ln:
                         (tbl[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, block_size, 1, d),
                         lambda ib, ih, ik, tbl, st, ln:
                         (tbl[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, 1, d),
                               lambda ib, ih, ik, tbl, st, ln:
                               (ib, 0, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, d), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, C, hq, d), q.dtype),
        interpret=interpret,
    )(tbl, starts, lens, q, k_pages, v_pages)


def _ctx_kernel_quant(tbl_ref, start_ref, len_ref, q_ref, k_ref, v_ref,
                      ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale, nb, block_size, C):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kpos = ik * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (C, block_size), 1)
    qpos = start_ref[ib] + jax.lax.broadcasted_iota(
        jnp.int32, (C, block_size), 0)
    mask = (kpos <= qpos) & (kpos < len_ref[ib])

    q = q_ref[0, :, 0].astype(jnp.float32)              # (C, d)
    k = k_ref[0, :, 0].astype(jnp.float32) \
        * ks_ref[0, :, 0].astype(jnp.float32)[:, None]  # (block_size, d)
    v = v_ref[0, :, 0].astype(jnp.float32) \
        * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[:, None]
        o = jnp.where(m_ref[...][:, None] <= NEG_INF / 2, 0.0, o)
        o_ref[0, :, 0] = o.astype(o_ref.dtype)


def paged_context_attention_quant_pallas(q, k_pages, v_pages, k_scale,
                                         v_scale, block_tables, *, q_start,
                                         kv_len, scale=None,
                                         interpret=False):
    """Quantized-pool context prefill: same contract as
    paged_context_attention_pallas with int8/fp8 payload pools plus
    (n_blocks,block_size,hkv) f32 scale pools."""
    b, C, hq, d = q.shape
    n_blocks, block_size, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    tbl = jnp.asarray(block_tables, jnp.int32)
    starts = jnp.asarray(q_start, jnp.int32)
    lens = jnp.asarray(kv_len, jnp.int32)

    page_spec = pl.BlockSpec((1, block_size, 1, d),
                             lambda ib, ih, ik, tbl, st, ln:
                             (tbl[ib, ik], 0, ih // g, 0))
    scale_spec = pl.BlockSpec((1, block_size, 1),
                              lambda ib, ih, ik, tbl, st, ln:
                              (tbl[ib, ik], 0, ih // g))
    kern = functools.partial(_ctx_kernel_quant, scale=scale, nb=nb,
                             block_size=block_size, C=C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, C, 1, d),
                         lambda ib, ih, ik, tbl, st, ln: (ib, 0, ih, 0)),
            page_spec,
            page_spec,
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, C, 1, d),
                               lambda ib, ih, ik, tbl, st, ln:
                               (ib, 0, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, d), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, C, hq, d), q.dtype),
        interpret=interpret,
    )(tbl, starts, lens, q, k_pages, v_pages, k_scale, v_scale)


# ---------------------------------------------------------------------------
# Paged MULTI-TOKEN VERIFICATION (speculative decoding): T candidate tokens
# per slot — the bonus token plus the draft proposals — run against the paged
# cache in ONE kernel launch. The per-slot KV-START offset (the slot's
# committed length) is the chunk origin: candidate j of slot i sits at
# absolute position kv_start[i] + j, attends to the committed pages
# [0, kv_start[i]) plus the candidate prefix up to itself, and the output is
# kept at EVERY position (acceptance needs the target's distribution after
# each candidate, not just the last). That is exactly the context grid with
# the start scalars re-interpreted per slot, so the verification path rides
# the same scalar-prefetch DMA routing — one grid, two serving roles.
# ---------------------------------------------------------------------------

def paged_verify_attention_pallas(q, k_pages, v_pages, block_tables, *,
                                  kv_start, kv_len, scale=None,
                                  interpret=False):
    """q (b,T,hq,d) — T candidates per slot, row i's candidate j at
    absolute position kv_start[i] + j; k_pages/v_pages
    (n_blocks,block_size,hkv,d) already hold the candidates' K/V at
    [kv_start, kv_len); block_tables (b,max_blocks) int32; kv_start,kv_len
    (b,). Rows with kv_len == kv_start are dead (all-masked, exact
    zeros). Returns (b,T,hq,d)."""
    return paged_context_attention_pallas(
        q, k_pages, v_pages, block_tables, q_start=kv_start, kv_len=kv_len,
        scale=scale, interpret=interpret)


def paged_verify_attention_quant_pallas(q, k_pages, v_pages, k_scale,
                                        v_scale, block_tables, *, kv_start,
                                        kv_len, scale=None, interpret=False):
    """Quantized-pool verification: the quantized context grid with the
    per-slot committed length as the chunk origin."""
    return paged_context_attention_quant_pallas(
        q, k_pages, v_pages, k_scale, v_scale, block_tables,
        q_start=kv_start, kv_len=kv_len, scale=scale, interpret=interpret)
