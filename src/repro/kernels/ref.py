"""Pure-jnp oracles for every kernel. Simple, obviously-correct, O(s^2) where
applicable. Tests assert the Pallas kernels and the chunked XLA paths in
ops.py against these.

Shape conventions:
  q:     (b, s_q, h_q, d)
  k, v:  (b, s_kv, h_kv, d)      h_q % h_kv == 0 (GQA)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    # -> (b, hkv, g, sq, skv)
    return jnp.einsum("bshgd,bthd->bhgst", qg, k)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                  kv_len=None, kv_start=None, scale=None):
    """Full materialized attention oracle.

    q_offset: absolute position of q[0] (for decode / chunked prefill).
    window:   sliding-window size (0 = full). Query at abs position p attends
              to keys in [p-window+1, p].
    kv_len:   optional (b,) valid KV lengths (positions >= len are masked).
    kv_start: optional (b,) first valid KV position (left-padding mask).
    """
    orig_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = _gqa_scores(q, k) * scale                      # (b,hkv,g,sq,skv)

    qpos = jnp.arange(sq) + q_offset                   # (sq,)
    kpos = jnp.arange(skv)                             # (skv,)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:
        lmask = kpos[None, :] < kv_len[:, None]        # (b,skv)
        s = jnp.where(lmask[:, None, None, None], s, NEG_INF)
    if kv_start is not None:
        smask = kpos[None, :] >= kv_start[:, None]     # (b,skv)
        s = jnp.where(smask[:, None, None, None], s, NEG_INF)

    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgst,bthd->bshgd", p / jnp.maximum(l, 1e-30), v)
    # fully-masked rows (e.g. pad queries) return exactly 0
    dead = (m <= NEG_INF / 2)
    o = jnp.where(jnp.moveaxis(dead, 3, 1), 0.0, o)
    return o.reshape(b, sq, hq, d).astype(orig_dtype)


def decode_attention_ref(q, k, v, *, kv_len=None, kv_start=None, window=0,
                         scale=None):
    """One-token decode oracle: q is (b, 1, hq, d); cache (b, S, hkv, d).

    With a sliding-window ring cache the caller passes the ring contents and
    kv_len = full cache size (every slot valid); ordering inside the ring
    does not matter for attention (softmax is permutation-invariant).
    """
    b, one, hq, d = q.shape
    assert one == 1
    skv = k.shape[1]
    if kv_len is None:
        kv_len = jnp.full((b,), skv, dtype=jnp.int32)
    # decode never needs the causal triangle: all cached keys are in the past.
    return attention_ref(q, k, v, causal=False, window=0, kv_len=kv_len,
                         kv_start=kv_start, scale=scale)


def gather_pages(pages, block_tables):
    """(n_blocks, bs, h, d) pages + (b, nb) tables -> contiguous
    (b, nb * bs, h, d) per-sequence caches (unallocated table entries
    gather the null page; callers mask them via kv_len)."""
    b, nb = block_tables.shape
    _, bs, h, d = pages.shape
    return pages[block_tables].reshape(b, nb * bs, h, d)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, *,
                               kv_len=None, scale=None):
    """Paged decode oracle: gather each sequence's pages into a contiguous
    cache, then run the contiguous decode oracle. q (b,1,hq,d);
    k_pages/v_pages (n_blocks, block_size, hkv, d); block_tables (b, nb)."""
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    b = q.shape[0]
    if kv_len is None:
        kv_len = jnp.full((b,), k.shape[1], jnp.int32)
    return decode_attention_ref(q, k, v, kv_len=kv_len, scale=scale)


def context_attention_ref(q, k, v, *, q_start, kv_len, scale=None):
    """CONTEXT-PREFILL oracle: a chunk of new tokens attending to the prior
    cache plus itself, causally — the warm-prefix / chunked-prefill primitive.

    q:       (b, C, hq, d) — query chunk; row i's token j sits at absolute
             position q_start[i] + j.
    k, v:    (b, S, hkv, d) — the FULL cache view (prior tokens at
             [0, q_start) plus the chunk's own K/V already written at
             [q_start, kv_len)).
    q_start: (b,) first absolute position of the chunk per row.
    kv_len:  (b,) valid cache length per row (= q_start + real chunk len;
             positions >= kv_len are masked).

    Query j of row i sees keys kpos <= q_start[i] + j and kpos < kv_len[i].
    Padding queries (j beyond the real chunk) produce garbage rows the
    caller discards; they still see a non-empty key set, so no NaNs.
    """
    orig_dtype = q.dtype
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    b, C, hq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None \
        else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = _gqa_scores(qf, kf) * scale                    # (b,hkv,g,C,skv)

    qpos = jnp.asarray(q_start, jnp.int32)[:, None] + jnp.arange(C)[None]
    kpos = jnp.arange(skv)
    mask = kpos[None, None, :] <= qpos[:, :, None]     # (b,C,skv) causal
    mask &= (kpos[None, :] < jnp.asarray(kv_len, jnp.int32)[:, None]
             )[:, None, :]
    s = jnp.where(mask[:, None, None], s, NEG_INF)

    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgst,bthd->bshgd", p / jnp.maximum(l, 1e-30), vf)
    dead = (m <= NEG_INF / 2)
    o = jnp.where(jnp.moveaxis(dead, 3, 1), 0.0, o)
    return o.reshape(b, C, hq, d).astype(orig_dtype)


def paged_context_attention_ref(q, k_pages, v_pages, block_tables, *,
                                q_start, kv_len, scale=None):
    """Paged context-prefill oracle: gather each row's pages (which already
    hold the chunk's K/V at [q_start, kv_len)) into a contiguous view, then
    run the context oracle."""
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    return context_attention_ref(q, k, v, q_start=q_start, kv_len=kv_len,
                                 scale=scale)


def paged_verify_attention_ref(q, k_pages, v_pages, block_tables, *,
                               kv_start, kv_len, scale=None):
    """MULTI-TOKEN VERIFICATION oracle (speculative decoding): q (b,T,hq,d)
    is a chunk of T candidate tokens per slot — the bonus token plus the
    draft proposals — whose row-i token j sits at absolute position
    kv_start[i] + j, i.e. the chunk begins at the per-slot COMMITTED KV
    length rather than a shared offset. Each candidate attends causally to
    the committed pages [0, kv_start[i]) plus the candidate prefix up to
    and including itself; the chunk's own K/V must already sit in the
    pages at [kv_start, kv_len). kv_len (b,) = kv_start + real candidate
    count (rows with kv_len == kv_start are dead and return exact zeros).

    The semantics coincide with the context-prefill oracle with the
    per-slot KV-start offset as the chunk origin — verification IS a
    context pass that keeps every position's output (the acceptance test
    needs the target's distribution after each candidate, not just the
    last)."""
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    return context_attention_ref(q, k, v, q_start=kv_start, kv_len=kv_len,
                                 scale=scale)


# ---------------------------------------------------------------------------
# Quantized paged oracles (int8/fp8 page pools with per-token-per-head
# scales; models/quant.py KV helpers). Each dequantizes the WHOLE pool to
# float32 pages and reuses the unquantized paged oracle — obviously correct,
# and the arithmetic (dequant before the f32 score dot) matches what the
# Pallas kernels fuse in-register, so exact-match tests are meaningful.
# ---------------------------------------------------------------------------

def dequant_pages(pages, scales):
    """(n_blocks, bs, h, d) quantized payload + (n_blocks, bs, h) f32
    scales -> float32 pages."""
    return pages.astype(jnp.float32) * scales[..., None].astype(jnp.float32)


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     block_tables, *, kv_len=None,
                                     scale=None):
    return paged_decode_attention_ref(
        q, dequant_pages(k_pages, k_scale), dequant_pages(v_pages, v_scale),
        block_tables, kv_len=kv_len, scale=scale)


def paged_context_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                      block_tables, *, q_start, kv_len,
                                      scale=None):
    return paged_context_attention_ref(
        q, dequant_pages(k_pages, k_scale), dequant_pages(v_pages, v_scale),
        block_tables, q_start=q_start, kv_len=kv_len, scale=scale)


def paged_verify_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     block_tables, *, kv_start, kv_len,
                                     scale=None):
    return paged_verify_attention_ref(
        q, dequant_pages(k_pages, k_scale), dequant_pages(v_pages, v_scale),
        block_tables, kv_start=kv_start, kv_len=kv_len, scale=scale)


def ssm_scan_ref(x, dt, A, B, C, D, *, h0=None):
    """Sequential selective-scan oracle (Mamba S6).

    x:  (b, s, din)      input after conv+silu
    dt: (b, s, din)      positive step sizes (already softplus'ed)
    A:  (din, ds)        negative real
    B:  (b, s, ds)
    C:  (b, s, ds)
    D:  (din,)
    h0: optional initial state (b, din, ds)
    Returns (y, h_final): y (b, s, din), h_final (b, din, ds).
    """
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    b, s, din = x.shape
    ds = A.shape[-1]
    h = jnp.zeros((b, din, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                          # (b,din),(b,din),(b,ds),(b,ds)
        dA = jnp.exp(dtt[..., None] * A[None])         # (b,din,ds)
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]   # (b,din,ds)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)            # (b,din)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None].astype(jnp.float32)
    return y.astype(x.dtype), h


def mlstm_scan_ref(q, k, v, i_gate, f_gate, *, C0=None, n0=None):
    """Sequential mLSTM oracle (softened sigmoid gating — see DESIGN.md).

    q,k: (b, s, h, dk)   v: (b, s, h, dv)
    i_gate, f_gate: (b, s, h) in (0,1)
    state C: (b, h, dk, dv), n: (b, h, dk)
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, 1)
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    i_f = i_gate.astype(jnp.float32)
    f_f = f_gate.astype(jnp.float32)
    C = jnp.zeros((b, h, dk, dv), jnp.float32) if C0 is None else C0.astype(jnp.float32)
    n = jnp.zeros((b, h, dk), jnp.float32) if n0 is None else n0.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(dk)

    def step(carry, inp):
        C, n = carry
        qt, kt, vt, it, ft = inp
        C = ft[..., None, None] * C + it[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = ft[..., None] * n + it[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt * scale, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qt * scale, n))
        y = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n), y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, i_f, f_f))
    (C, n), ys = jax.lax.scan(step, (C, n), xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), (C, n)
