"""jit-ready kernel entry points used by the model code.

Each op has (i) a chunked, memory-frugal XLA implementation (the default on
CPU and the dry-run lowering path — flash-style online softmax / chunked scan
so 32k-500k sequences never materialize O(s^2) score tensors), and (ii) an
optional Pallas TPU kernel behind ``set_backend("pallas")`` (validated in
interpret mode by tests). The oracles live in ref.py.
"""
from __future__ import annotations

import contextlib
import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_BACKEND = "xla"          # "xla" | "pallas" | "pallas_interpret"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("xla", "pallas", "pallas_interpret"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend(name: str):
    """Scoped backend switch: ``with ops.backend("pallas_interpret"): ...``
    restores the previous backend even on error, so a failing kernel check
    can't leak the global into every later test in the process."""
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


# ---------------------------------------------------------------------------
# Flash attention (prefill / training)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, kv_len=None,
                    kv_start=None, q_block=512, kv_block=512, scale=None):
    """Chunked attention. q (b,sq,hq,d); k,v (b,skv,hkv,d); GQA via hq%hkv==0.

    window > 0: sliding-window (each query sees the previous `window` keys,
    inclusive of itself) -- computed sub-quadratically via a static-width KV
    slice per query block.
    """
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, kv_len=kv_len,
            kv_start=kv_start, q_block=q_block, kv_block=kv_block,
            scale=scale, interpret=(_BACKEND == "pallas_interpret"))
    return _flash_attention_xla(q, k, v, causal=causal, window=window,
                                kv_len=kv_len, kv_start=kv_start,
                                q_block=q_block, kv_block=kv_block,
                                scale=scale)


def _flash_attention_xla(q, k, v, *, causal, window, kv_len, kv_start,
                         q_block, kv_block, scale):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    if window >= skv:
        window = 0                  # full-width band == plain causal
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    if sq % q_block or (window == 0 and skv % kv_block):
        # Small/odd shapes (tests): fall back to the oracle.
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 kv_len=kv_len, kv_start=kv_start,
                                 scale=scale)
    if window == 0:
        # flash path with FA2-style custom VJP: the backward recomputes
        # p blockwise instead of saving O(s^2) probabilities
        return _fa_full(causal, q_block, kv_block, scale, q, k, v,
                        kv_len, kv_start)

    nq = sq // q_block
    qf = q.astype(jnp.float32).reshape(b, nq, q_block, hkv, hq // hkv, d)
    qf = jnp.moveaxis(qf, 1, 0)                        # (nq,b,qblk,hkv,g,d)
    out = _swa_blocks(qf, k.astype(jnp.float32), v.astype(jnp.float32),
                      window=window, q_block=q_block, kv_len=kv_len,
                      kv_start=kv_start, causal=causal, scale=scale)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# FA2-style custom VJP for the full (non-windowed) flash path
# ---------------------------------------------------------------------------

def _fa_fwd_blocks(causal, q_block, kv_block, scale, q, k, v, kv_len,
                   kv_start):
    """Returns (out (b,sq,hq,d) f32-accumulated, lse (b,hkv,g,sq))."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    nq = sq // q_block
    qf = jnp.moveaxis(
        q.astype(jnp.float32).reshape(b, nq, q_block, hkv, g, d), 1, 0)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    nk = skv // kv_block
    kb = jnp.moveaxis(kf.reshape(b, nk, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(vf.reshape(b, nk, kv_block, hkv, d), 1, 0)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, kj_and_idx):
            m, l, acc = carry
            kj, vj, jk = kj_and_idx
            s = _masked_scores(qi, kj, qpos, jk * kv_block, kv_block,
                               causal, kv_len, kv_start, scale)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.where(s <= ref.NEG_INF / 2, 0.0,
                          jnp.exp(s - m_new[..., None]))
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd",
                                                     p, vj)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_block), ref.NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kb, vb, jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(m <= ref.NEG_INF / 2, 0.0,
                        m + jnp.log(jnp.maximum(l, 1e-30)))
        return None, (jnp.moveaxis(o, 3, 1), lse)

    _, (out, lse) = jax.lax.scan(q_step, None, (qf, jnp.arange(nq)))
    # out: (nq,b,qblk,hkv,g,d); lse: (nq,b,hkv,g,qblk)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, hkv, g, sq)
    return out, lse


def _masked_scores(qi, kj, qpos, kstart, kv_block, causal, kv_len, kv_start,
                   scale):
    kpos = kstart + jnp.arange(kv_block)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj) * scale
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, ref.NEG_INF)
    if kv_len is not None:
        lm = kpos[None, :] < kv_len[:, None]
        s = jnp.where(lm[:, None, None, None, :], s, ref.NEG_INF)
    if kv_start is not None:
        sm = kpos[None, :] >= kv_start[:, None]
        s = jnp.where(sm[:, None, None, None, :], s, ref.NEG_INF)
    return s


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _fa_full(causal, q_block, kv_block, scale, q, k, v, kv_len, kv_start):
    out, _ = _fa_fwd_blocks(causal, q_block, kv_block, scale, q, k, v,
                            kv_len, kv_start)
    return out.astype(q.dtype)


def _fa_full_fwd(causal, q_block, kv_block, scale, q, k, v, kv_len, kv_start):
    out, lse = _fa_fwd_blocks(causal, q_block, kv_block, scale, q, k, v,
                              kv_len, kv_start)
    out = out.astype(q.dtype)
    return out, (q, k, v, out, lse, kv_len, kv_start)


def _fa_full_bwd(causal, q_block, kv_block, scale, res, do):
    q, k, v, o, lse, kv_len, kv_start = res
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    nq, nk = sq // q_block, skv // kv_block

    dof = do.astype(jnp.float32)
    of = o.astype(jnp.float32)
    # D_i = rowsum(do * o): (b,hkv,g,sq)
    Dx = jnp.moveaxis((dof * of).sum(-1).reshape(b, sq, hkv, g), 1, 3)

    def rq(t):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(b, nq, q_block, hkv, g, d), 1, 0)

    qb = rq(q)
    dob = rq(do)
    kb = jnp.moveaxis(
        k.astype(jnp.float32).reshape(b, nk, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(
        v.astype(jnp.float32).reshape(b, nk, kv_block, hkv, d), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(b, hkv, g, nq, q_block), 3, 0)
    Db = jnp.moveaxis(Dx.reshape(b, hkv, g, nq, q_block), 3, 0)

    def kv_step(dq_acc, kj_and):
        kj, vj, jk = kj_and

        def q_step(carry, qi_and):
            dk_j, dv_j = carry
            qi, doi, lse_i, D_i, iq = qi_and
            qpos = iq * q_block + jnp.arange(q_block)
            s = _masked_scores(qi, kj, qpos, jk * kv_block, kv_block,
                               causal, kv_len, kv_start, scale)
            p = jnp.where(s <= ref.NEG_INF / 2, 0.0,
                          jnp.exp(s - lse_i[..., None]))
            dv_j = dv_j + jnp.einsum("bhgqk,bqhgd->bkhd", p, doi)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doi, vj)
            ds = p * (dp - D_i[..., None]) * scale
            dk_j = dk_j + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qi)
            dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj)
            return (dk_j, dv_j), dq_i

        z = jnp.zeros((b, kv_block, hkv, d), jnp.float32)
        (dk_j, dv_j), dq_contrib = jax.lax.scan(
            q_step, (z, z), (qb, dob, lseb, Db, jnp.arange(nq)))
        # dq_contrib: (nq,b,qblk,hkv,g,d)
        dq_acc = dq_acc + jnp.moveaxis(dq_contrib, 0, 1).reshape(
            b, sq, hq, d)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, hq, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (kb, vb, jnp.arange(nk)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, skv, hkv, d)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, skv, hkv, d)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_fa_full.defvjp(_fa_full_fwd, _fa_full_bwd)


def _full_blocks(qf, kf, vf, *, kv_block, q_block, kv_len, kv_start, causal,
                 scale):
    nq, b, _, hkv, g, d = qf.shape
    skv = kf.shape[1]
    nk = skv // kv_block
    kb = kf.reshape(b, nk, kv_block, hkv, d)
    vb = vf.reshape(b, nk, kv_block, hkv, d)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx                            # (b,qblk,hkv,g,d), scalar
        qpos = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, kj_and_idx):
            m, l, acc = carry
            kj, vj, jk = kj_and_idx
            kpos = jk * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj) * scale
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None, None], s, ref.NEG_INF)
            if kv_len is not None:
                lm = kpos[None, :] < kv_len[:, None]
                s = jnp.where(lm[:, None, None, None, :], s, ref.NEG_INF)
            if kv_start is not None:
                sm = kpos[None, :] >= kv_start[:, None]
                s = jnp.where(sm[:, None, None, None, :], s, ref.NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.where(s <= ref.NEG_INF / 2, 0.0,
                          jnp.exp(s - m_new[..., None]))
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vj)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_block), ref.NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]     # (b,hkv,g,qblk,d)
        return None, jnp.moveaxis(o, 3, 1)             # (b,qblk,hkv,g,d)

    _, out = jax.lax.scan(q_step, None, (qf, jnp.arange(nq)))
    return out


def _swa_blocks(qf, kf, vf, *, window, q_block, kv_len, kv_start, causal,
                scale):
    """Sliding window: per q block, slice a static (window + q_block)-wide KV
    band -- FLOPs scale with s*window, not s^2."""
    nq, b, _, hkv, g, d = qf.shape
    skv = kf.shape[1]
    wlen = min(window + q_block, skv)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qstart = iq * q_block
        start = jnp.maximum(qstart + q_block - wlen, 0)
        start = jnp.minimum(start, skv - wlen)
        kj = jax.lax.dynamic_slice_in_dim(kf, start, wlen, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(vf, start, wlen, axis=1)
        qpos = qstart + jnp.arange(q_block)
        kpos = start + jnp.arange(wlen)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj) * scale
        mask = jnp.ones((q_block, wlen), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        mask &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, ref.NEG_INF)
        if kv_len is not None:
            lm = kpos[None, :] < kv_len[:, None]
            s = jnp.where(lm[:, None, None, None, :], s, ref.NEG_INF)
        if kv_start is not None:
            sm = kpos[None, :] >= kv_start[:, None]
            s = jnp.where(sm[:, None, None, None, :], s, ref.NEG_INF)
        m = s.max(-1, keepdims=True)
        p = jnp.where(s <= ref.NEG_INF / 2, 0.0, jnp.exp(s - m))
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vj) / jnp.maximum(
            p.sum(-1, keepdims=True), 1e-30)
        return None, jnp.moveaxis(o, 3, 1)

    _, out = jax.lax.scan(q_step, None, (qf, jnp.arange(nq)))
    return out


# ---------------------------------------------------------------------------
# Decode attention (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, *, kv_len=None, kv_start=None, kv_block=0,
                     scale=None):
    """q (b,1,hq,d) against cache k,v (b,S,hkv,d). kv_len (b,) valid lengths.

    The XLA path materializes (b,hq,1,S) scores -- tiny even at 500k -- and
    keeps the cache in its storage dtype (bf16 MXU dot with f32 accumulation
    via preferred_element_type) instead of materializing an f32 copy: decode
    is HBM-bandwidth-bound on the cache stream (EXPERIMENTS.md §Perf).
    kv_block requests the Pallas flash-decode kernel's block size.
    """
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as da
        return da.decode_attention_pallas(
            q, k, v, kv_len=kv_len, kv_start=kv_start,
            kv_block=kv_block or 512, scale=scale,
            interpret=(_BACKEND == "pallas_interpret"))
    return _decode_attention_xla(q, k, v, kv_len=kv_len, kv_start=kv_start,
                                 scale=scale)


def _decode_attention_xla(q, k, v, *, kv_len, kv_start, scale):
    b, one, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(skv)
    if kv_len is not None:
        s = jnp.where((kpos[None] < kv_len[:, None])[:, None, None],
                      s, ref.NEG_INF)
    if kv_start is not None:
        s = jnp.where((kpos[None] >= kv_start[:, None])[:, None, None],
                      s, ref.NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(s <= ref.NEG_INF / 2, 0.0, jnp.exp(s - m))
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgt,bthd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    o = jnp.where(m <= ref.NEG_INF / 2, 0.0, o)
    return o.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode attention (one new token against a block-paged cache)
# ---------------------------------------------------------------------------

def paged_decode_attention(q, k_pages, v_pages, block_tables, *, kv_len=None,
                           scale=None, k_scale=None, v_scale=None):
    """q (b,1,hq,d) against a paged cache: k_pages/v_pages
    (n_blocks, block_size, hkv, d) shared by all sequences, block_tables
    (b, max_blocks) int32 mapping logical block j of row i to a physical
    page, kv_len (b,) valid lengths.

    The XLA path gathers each row's pages into a contiguous (b, S, hkv, d)
    view and reuses the contiguous decode kernel — with S equal to the
    contiguous slot length this is BIT-IDENTICAL to contiguous decode (the
    gathered values match everywhere attention can look, and masked tail
    positions contribute exact zeros either way). The Pallas path streams
    pages directly through the block table (kernels.paged_attention) and
    never materializes the gather.

    k_scale/v_scale (n_blocks, block_size, hkv) f32 mark a QUANTIZED pool
    (int8/fp8 payload, models/quant.py): the Pallas path fuses the dequant
    in-register before the score dot, the XLA path dequantizes the pool and
    gathers — both match ref.paged_decode_attention_quant_ref.
    """
    if k_scale is not None:
        if _BACKEND in ("pallas", "pallas_interpret"):
            from repro.kernels import paged_attention as pa
            return pa.paged_decode_attention_quant_pallas(
                q, k_pages, v_pages, k_scale, v_scale, block_tables,
                kv_len=kv_len, scale=scale,
                interpret=(_BACKEND == "pallas_interpret"))
        k = ref.gather_pages(ref.dequant_pages(k_pages, k_scale),
                             block_tables)
        v = ref.gather_pages(ref.dequant_pages(v_pages, v_scale),
                             block_tables)
        if kv_len is None:
            kv_len = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
        return _decode_attention_xla(q, k, v, kv_len=kv_len, kv_start=None,
                                     scale=scale)
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import paged_attention as pa
        return pa.paged_decode_attention_pallas(
            q, k_pages, v_pages, block_tables, kv_len=kv_len, scale=scale,
            interpret=(_BACKEND == "pallas_interpret"))
    k = ref.gather_pages(k_pages, block_tables)
    v = ref.gather_pages(v_pages, block_tables)
    if kv_len is None:
        kv_len = jnp.full((q.shape[0],), k.shape[1], jnp.int32)
    return _decode_attention_xla(q, k, v, kv_len=kv_len, kv_start=None,
                                 scale=scale)


def paged_context_attention(q, k_pages, v_pages, block_tables, *, q_start,
                            kv_len, scale=None, k_scale=None, v_scale=None):
    """CONTEXT PREFILL against a block-paged cache: q (b,C,hq,d) is a chunk
    of new tokens (row i's token j at absolute position q_start[i] + j)
    attending causally to the prior pages AND itself — the chunk's K/V must
    already be scattered into the pages at [q_start, kv_len) through the
    same block tables (layers.attn_context_paged does the write).

    This is the kernel behind warm-prefix serving (only the cold suffix of
    a prompt runs as the chunk, the shared prefix is reused from resident
    pages) and chunked prefill (a long prompt runs as several chunks
    interleaved with decode iterations). The XLA path gathers each row's
    pages into a contiguous view and materializes the (C, S) score tile —
    C is a bounded chunk width, so this stays small; the Pallas path
    streams pages through the block table with online softmax
    (kernels.paged_attention.paged_context_attention_pallas).

    k_scale/v_scale mark a quantized pool, as in paged_decode_attention.
    """
    if k_scale is not None:
        if _BACKEND in ("pallas", "pallas_interpret"):
            from repro.kernels import paged_attention as pa
            return pa.paged_context_attention_quant_pallas(
                q, k_pages, v_pages, k_scale, v_scale, block_tables,
                q_start=q_start, kv_len=kv_len, scale=scale,
                interpret=(_BACKEND == "pallas_interpret"))
        k = ref.gather_pages(ref.dequant_pages(k_pages, k_scale),
                             block_tables)
        v = ref.gather_pages(ref.dequant_pages(v_pages, v_scale),
                             block_tables)
        return ref.context_attention_ref(q, k, v, q_start=q_start,
                                         kv_len=kv_len, scale=scale)
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import paged_attention as pa
        return pa.paged_context_attention_pallas(
            q, k_pages, v_pages, block_tables, q_start=q_start,
            kv_len=kv_len, scale=scale,
            interpret=(_BACKEND == "pallas_interpret"))
    k = ref.gather_pages(k_pages, block_tables)
    v = ref.gather_pages(v_pages, block_tables)
    return ref.context_attention_ref(q, k, v, q_start=q_start,
                                     kv_len=kv_len, scale=scale)


def paged_verify_attention(q, k_pages, v_pages, block_tables, *, kv_start,
                           kv_len, scale=None, k_scale=None, v_scale=None):
    """MULTI-TOKEN VERIFICATION against a block-paged cache (speculative
    decoding): q (b,T,hq,d) is each slot's candidate chunk — the bonus
    token plus up to T-1 draft proposals — whose row-i token j sits at
    absolute position kv_start[i] + j, the slot's per-request committed KV
    length. Candidates attend causally to the committed pages
    [0, kv_start[i]) AND the candidate prefix up to themselves; their K/V
    must already be scattered into the pages at [kv_start, kv_len)
    (layers.attn_verify_paged does the write). Unlike the context-prefill
    entry, callers consume the output at EVERY chunk position: greedy (or
    rejection-sampling) acceptance compares the target's argmax after
    candidate j against candidate j+1, so all T distributions matter.

    Rows with kv_len == kv_start carry zero real candidates (free /
    mid-prefill slots riding the joint dispatch) and come back as exact
    zeros. The Pallas path streams pages through the block table on the
    context grid with per-slot start offsets
    (kernels.paged_attention.paged_verify_attention_pallas); the XLA path
    gathers pages into a contiguous view and runs the oracle — T is k+1,
    a handful of tokens, so the (T, S) score tile stays tiny.

    k_scale/v_scale mark a quantized pool, as in paged_decode_attention.
    """
    if k_scale is not None:
        if _BACKEND in ("pallas", "pallas_interpret"):
            from repro.kernels import paged_attention as pa
            return pa.paged_verify_attention_quant_pallas(
                q, k_pages, v_pages, k_scale, v_scale, block_tables,
                kv_start=kv_start, kv_len=kv_len, scale=scale,
                interpret=(_BACKEND == "pallas_interpret"))
        k = ref.gather_pages(ref.dequant_pages(k_pages, k_scale),
                             block_tables)
        v = ref.gather_pages(ref.dequant_pages(v_pages, v_scale),
                             block_tables)
        return ref.context_attention_ref(q, k, v, q_start=kv_start,
                                         kv_len=kv_len, scale=scale)
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import paged_attention as pa
        return pa.paged_verify_attention_pallas(
            q, k_pages, v_pages, block_tables, kv_start=kv_start,
            kv_len=kv_len, scale=scale,
            interpret=(_BACKEND == "pallas_interpret"))
    k = ref.gather_pages(k_pages, block_tables)
    v = ref.gather_pages(v_pages, block_tables)
    return ref.context_attention_ref(q, k, v, q_start=kv_start,
                                     kv_len=kv_len, scale=scale)


# ---------------------------------------------------------------------------
# Selective scan (Mamba S6)
# ---------------------------------------------------------------------------

def ssm_scan(x, dt, A, B, C, D, *, h0=None, chunk=128):
    """Chunked selective scan; see ref.ssm_scan_ref for semantics."""
    if _BACKEND in ("pallas", "pallas_interpret"):
        from repro.kernels import ssm_scan as sk
        return sk.ssm_scan_pallas(x, dt, A, B, C, D, h0=h0, chunk=chunk,
                                  interpret=(_BACKEND == "pallas_interpret"))
    return _ssm_scan_xla(x, dt, A, B, C, D, h0=h0, chunk=chunk)


def _ssm_scan_xla(x, dt, A, B, C, D, *, h0, chunk):
    b, s, din = x.shape
    ds = A.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> identity step
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, din)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, din)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, ds)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, ds)
    Af = A.astype(jnp.float32)

    h = jnp.zeros((b, din, ds), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    y, h = _ssm_chunks(xf, dtf, Bf, Cf, Af, h)
    y = y.reshape(b, sp, din)[:, :s]
    y = y + x.astype(jnp.float32)[:, :s] * D[None, None].astype(jnp.float32)
    return y.astype(x.dtype), h


def _ssm_chunk_step(Af, h, xc, dtc, Bc, Cc):
    """One chunk of the selective scan: (h, (b,c,*) inputs) -> (h', y)."""
    a = jnp.exp(dtc[..., None] * Af[None, None])       # (b,c,din,ds)
    bb = (dtc * xc)[..., None] * Bc[:, :, None, :]

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, h_intra = jax.lax.associative_scan(comb, (a, bb), axis=1)
    h_all = h_intra + a_cum * h[:, None]
    y = jnp.einsum("bcdn,bcn->bcd", h_all, Cc)
    return h_all[:, -1], y


@jax.custom_vjp
def _ssm_chunks(xf, dtf, Bf, Cf, Af, h0):
    """Chunk-scan with recompute-in-backward: forward saves only the
    chunk-boundary states (O(s/chunk)), backward re-runs each chunk under
    jax.vjp in reverse -- the O(s * d_state) scan internals never persist."""
    y, h, _ = _ssm_chunks_fwd_impl(xf, dtf, Bf, Cf, Af, h0)
    return y, h


def _ssm_chunks_fwd_impl(xf, dtf, Bf, Cf, Af, h0):
    def step(h, inp):
        xc, dtc, Bc, Cc = inp
        h2, y = _ssm_chunk_step(Af, h, xc, dtc, Bc, Cc)
        return h2, (y, h)                      # save ENTRY state per chunk

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf))
    h, (ys, h_ins) = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(xf.shape), h, h_ins


def _ssm_chunks_fwd(xf, dtf, Bf, Cf, Af, h0):
    y, h, h_ins = _ssm_chunks_fwd_impl(xf, dtf, Bf, Cf, Af, h0)
    return (y, h), (xf, dtf, Bf, Cf, Af, h_ins)


def _ssm_chunks_bwd(res, cts):
    xf, dtf, Bf, Cf, Af, h_ins = res
    dy, dh_out = cts
    b, nc, c, din = xf.shape
    dyc = jnp.moveaxis(dy.reshape(b, nc, c, din), 1, 0)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf))

    def rev_step(carry, inp):
        lam, dA = carry                        # cotangent wrt chunk-exit h
        xc, dtc, Bc, Cc, h_in, dy_c = inp

        def f(h, xc, dtc, Bc, Cc, A):
            return _ssm_chunk_step(A, h, xc, dtc, Bc, Cc)

        _, vjp = jax.vjp(f, h_in, xc, dtc, Bc, Cc, Af)
        dh_in, dxc, ddtc, dBc, dCc, dA_i = vjp((lam, dy_c))
        return (dh_in, dA + dA_i), (dxc, ddtc, dBc, dCc)

    xs_rev = tuple(t[::-1] for t in xs) + (h_ins[::-1], dyc[::-1])
    (dh0, dA), (dx, ddt, dB, dC) = jax.lax.scan(
        rev_step, (dh_out, jnp.zeros_like(Af)), xs_rev)
    unrev = lambda t: jnp.moveaxis(t[::-1], 0, 1)
    return unrev(dx), unrev(ddt), unrev(dB), unrev(dC), dA, dh0


_ssm_chunks.defvjp(_ssm_chunks_fwd, _ssm_chunks_bwd)


def ssm_step(x_t, dt_t, A, B_t, C_t, D, h):
    """Single decode step. x_t,dt_t (b,din); B_t,C_t (b,ds); h (b,din,ds)."""
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * Af[None])
    dBx = (dtf * xf)[..., None] * B_t.astype(jnp.float32)[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
    y = y + xf * D.astype(jnp.float32)[None]
    return y.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# mLSTM chunked linear attention
# ---------------------------------------------------------------------------

def mlstm_scan(q, k, v, i_gate, f_gate, *, C0=None, n0=None, chunk=128):
    """Chunked mLSTM; see ref.mlstm_scan_ref. Gates in (0,1) (sigmoid)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, zq)
        k = jnp.pad(k, zq)
        v = jnp.pad(v, zq)
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))        # i=0
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=1.0)                        # f=1
    sp = s + pad
    nc = sp // chunk
    scale = 1.0 / (dk ** 0.5)

    def r(t, last):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(b, nc, chunk, h, last), 1, 0)

    qs, ks, vs = r(q, dk), r(k, dk), r(v, dv)
    i_s = jnp.moveaxis(i_gate.astype(jnp.float32).reshape(b, nc, chunk, h), 1, 0)
    f_s = jnp.moveaxis(f_gate.astype(jnp.float32).reshape(b, nc, chunk, h), 1, 0)

    C = jnp.zeros((b, h, dk, dv), jnp.float32) if C0 is None else C0.astype(jnp.float32)
    n = jnp.zeros((b, h, dk), jnp.float32) if n0 is None else n0.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C, n = carry
        qc, kc, vc, ic, fc = inp                       # (b,c,h,*)
        logf = jnp.log(jnp.maximum(fc, 1e-30))         # (b,c,h)
        cum = jnp.cumsum(logf, axis=1)                 # log F_t
        # intra-chunk: decay[t,s] = exp(cum_t - cum_s) for s <= t (<= 1)
        dec = jnp.exp(jnp.clip(cum[:, :, None] - cum[:, None, :], None, 0.0))
        sc = jnp.einsum("bthd,bshd->bhts", qc * scale, kc)
        sc = sc * jnp.moveaxis(dec * ic[:, None, :, :], 3, 1)  # *(i_s) on s axis
        sc = jnp.where(tri[None, None], sc, 0.0)
        Ft = jnp.exp(cum)                              # (b,c,h)
        q_dec = qc * scale * Ft[..., None]
        num = jnp.einsum("bhts,bshd->bthd", sc, vc) + jnp.einsum(
            "bthk,bhkv->bthv", q_dec, C)
        den_intra = jnp.moveaxis(sc.sum(-1), 1, 2)     # (b,t,h)
        den_inter = jnp.einsum("bthk,bhk->bth", q_dec, n)
        den = jnp.abs(den_intra + den_inter)
        y = num / jnp.maximum(den, 1.0)[..., None]
        # carry update
        Fc = Ft[:, -1]                                 # (b,h) total decay
        rdec = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, None, 0.0)) * ic  # F_c/F_s * i_s
        kiv = jnp.einsum("bshk,bsh,bshv->bhkv", kc, rdec, vc)
        kin = jnp.einsum("bshk,bsh->bhk", kc, rdec)
        C = Fc[..., None, None] * C + kiv
        n = Fc[..., None] * n + kin
        return (C, n), y

    (C, n), ys = jax.lax.scan(chunk_step, (C, n), (qs, ks, vs, i_s, f_s))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, h, dv)[:, :s]
    return y.astype(q.dtype), (C, n)


def mlstm_step(q_t, k_t, v_t, i_t, f_t, C, n):
    """Single decode step. q_t,k_t (b,h,dk); v_t (b,h,dv); gates (b,h)."""
    dk = q_t.shape[-1]
    scale = 1.0 / (dk ** 0.5)
    qf = q_t.astype(jnp.float32) * scale
    C = f_t[..., None, None] * C + i_t[..., None, None] * (
        k_t.astype(jnp.float32)[..., :, None] * v_t.astype(jnp.float32)[..., None, :])
    n = f_t[..., None] * n + i_t[..., None] * k_t.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n))
    y = num / jnp.maximum(den, 1.0)[..., None]
    return y.astype(q_t.dtype), (C, n)
