"""llama2-70b — the paper's own served model [arXiv:2307.09288].

Used by the HexGen scheduling reproduction (cost model, case study, SLO
benchmarks). H=8192, L=80 matches Table 1's 12H^2-per-layer approximation.
"""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="llama2-70b",
    source="arXiv:2307.09288",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
))
