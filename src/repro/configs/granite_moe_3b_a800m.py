"""granite-moe-3b-a800m — IBM Granite MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    top_k=8,
))
