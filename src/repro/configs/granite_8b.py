"""granite-8b — IBM Granite Code 8B, llama-arch dense GQA [arXiv:2405.04324]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="granite-8b",
    source="arXiv:2405.04324",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
))
