"""Config system: a single ModelConfig dataclass covers every assigned family.

Every architecture file in this package instantiates one ModelConfig with the
exact published numbers and registers it under its ``--arch`` id.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds used by hybrid interleaves.
ATTN = "attn"          # full (or sliding-window) self-attention block
MAMBA = "mamba"        # selective-SSM block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    source: str                      # citation: arXiv id / hf model card
    family: str                      # dense | moe | hybrid | ssm | vlm | audio

    # Transformer backbone.
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    attn_bias: bool = False          # qwen-style qkv bias
    activation: str = "silu"         # silu (SwiGLU) | gelu (plain MLP)

    # Sliding-window attention (0 = full attention).
    swa_window: int = 0

    # MoE (num_experts = 0 -> dense MLP).
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1               # MoE MLP on layers where i % moe_every == moe_offset
    moe_offset: int = 0

    # Hybrid interleave: layer i kind = layer_pattern[i % len(layer_pattern)].
    # None -> all-ATTN.
    layer_pattern: Optional[Tuple[str, ...]] = None

    # SSM (mamba) block params.
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # xLSTM
    xlstm_qk_dim_factor: float = 0.5

    # Encoder-decoder (whisper): encoder stack mirrors decoder dims.
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper-base 30s -> 1500 frames (stubbed)

    # VLM: number of stub patch-embedding positions prepended to the prompt.
    num_image_tokens: int = 0

    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def layer_kind(self, i: int) -> str:
        if self.layer_pattern is None:
            return ATTN
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and i % self.moe_every == self.moe_offset

    @property
    def attn_layer_ids(self):
        return [i for i in range(self.num_layers) if self.layer_kind(i) == ATTN]

    # Parameter counts (for cost model + roofline MODEL_FLOPS).
    def params_per_layer(self, i: int) -> int:
        d, f, hd = self.d_model, self.d_ff, self.head_dim_
        kind = self.layer_kind(i)
        n = 0
        if kind == ATTN:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            n += q + kv + o
        elif kind == MAMBA:
            d_in = self.ssm_expand * d
            dt_rank = self.ssm_dt_rank or -(-d // 16)
            n += d * 2 * d_in                      # in_proj
            n += d_in * self.ssm_d_conv            # conv
            n += d_in * (dt_rank + 2 * self.ssm_d_state)  # x_proj
            n += dt_rank * d_in                    # dt_proj
            n += d_in * self.ssm_d_state           # A_log
            n += d_in                              # D
            n += d_in * d                          # out_proj
        elif kind in (MLSTM, SLSTM):
            d_in = self.ssm_expand * d if kind == MLSTM else d
            qk = int(d_in * self.xlstm_qk_dim_factor)
            n += d * d_in * 2 if kind == MLSTM else 0
            n += d_in * (2 * qk + d_in)            # q,k,v projections
            n += 3 * d_in                          # gates (i,f,o) per-unit
            n += d_in * d                          # out proj
        # MLP / MoE
        if kind == ATTN or (self.layer_pattern is not None and kind == MAMBA):
            mlp = 3 * d * f if self.activation == "silu" else 2 * d * f
            if self.is_moe_layer(i):
                n += self.num_experts * mlp + d * self.num_experts
            elif f > 0:
                n += mlp
        return n

    def active_params_per_layer(self, i: int) -> int:
        """Params touched per token (MoE counts top_k experts only)."""
        n = self.params_per_layer(i)
        if self.is_moe_layer(i):
            d, f = self.d_model, self.d_ff
            mlp = 3 * d * f if self.activation == "silu" else 2 * d * f
            n -= (self.num_experts - self.top_k) * mlp
        return n

    @property
    def total_params(self) -> int:
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        n += sum(self.params_per_layer(i) for i in range(self.num_layers))
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted above,
            # add decoder cross-attn.
            d, f, hd = self.d_model, self.d_ff, self.head_dim_
            attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            mlp = 2 * d * f  # gelu
            n += self.num_encoder_layers * (attn + mlp)
            n += self.num_layers * attn            # cross attention
        return n

    @property
    def active_params(self) -> int:
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        n += sum(self.active_params_per_layer(i) for i in range(self.num_layers))
        return n

    def kv_cache_bytes_per_token_layer(self, i: int, bytes_per_el: int = 2) -> int:
        """Per-token, per-layer recurrent/cache footprint in bytes."""
        kind = self.layer_kind(i)
        if kind == ATTN:
            eff_kv = self.num_kv_heads
            return 2 * eff_kv * self.head_dim_ * bytes_per_el
        # SSM-ish layers carry O(1) state, amortized per token -> 0 growth.
        return 0

    def reduced(self) -> "ModelConfig":
        """2-layer, d_model<=512, <=4-expert variant of the same family for smoke tests."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        # keep the GQA/MQA character: preserve ratio when possible
        if self.num_kv_heads == 1:
            kv = 1
        elif self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        else:
            kv = heads
        pattern = self.layer_pattern
        nl = 2 if pattern is None else max(2, len(pattern))
        if pattern is not None and len(pattern) > 4:
            # shrink hybrid pattern but keep at least one of each kind
            kinds = []
            for k in pattern:
                if k not in kinds:
                    kinds.append(k)
            pattern = tuple(kinds * 2)[:4]
            nl = len(pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=nl,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=0,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            layer_pattern=pattern,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else self.encoder_seq_len,
            num_image_tokens=8 if self.num_image_tokens else 0,
            swa_window=min(self.swa_window, 64) if self.swa_window else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
