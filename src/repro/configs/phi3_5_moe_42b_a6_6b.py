"""phi3.5-moe-42b-a6.6b — Microsoft Phi-3.5-MoE, 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    top_k=2,
))
