"""whisper-base — OpenAI Whisper base, encoder-decoder; mel-spectrogram +
conv frontend is STUBBED (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-base",
    source="arXiv:2212.04356",
    family="audio",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq_len=1500,
    rope_theta=0.0,               # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
))
