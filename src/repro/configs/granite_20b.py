"""granite-20b — IBM Granite Code 20B, MQA (kv=1) dense [arXiv:2405.04324]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="granite-20b",
    source="arXiv:2405.04324",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",  # gpt-bigcode style MLP
))
