"""Architecture registry. ``get_config("granite-8b")`` etc."""
from __future__ import annotations

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES  # noqa: F401

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        granite_8b, jamba_v0_1_52b, h2o_danube_1_8b, granite_moe_3b_a800m,
        granite_20b, xlstm_125m, paligemma_3b, codeqwen1_5_7b,
        phi3_5_moe_42b_a6_6b, whisper_base, llama2_70b,
    )
    _LOADED = True
