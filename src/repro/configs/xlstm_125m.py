"""xlstm-125m — sLSTM + mLSTM blocks, attention-free [arXiv:2405.04517].

12 blocks; sLSTM at 1-of-4 positions (xLSTM[x:y] style interleave), the rest
mLSTM. d_ff=0: xLSTM blocks carry their own projections, no separate MLP.
"""
from repro.configs import register
from repro.configs.base import MLSTM, SLSTM, ModelConfig

_PATTERN = (MLSTM, MLSTM, MLSTM, SLSTM)

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    source="arXiv:2405.04517",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=_PATTERN,
    ssm_expand=2,
    xlstm_qk_dim_factor=0.5,
))
