"""codeqwen1.5-7b — Qwen1.5 arch (MHA kv=32, attention bias)
[hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="codeqwen1.5-7b",
    source="hf:Qwen/CodeQwen1.5-7B",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    attn_bias=True,
    rope_theta=1_000_000.0,
))
