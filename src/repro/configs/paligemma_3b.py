"""paligemma-3b — SigLIP + gemma VLM; this config is the gemma-style language
backbone; the SigLIP vision tower + projector are STUBBED (input_specs provides
precomputed patch embeddings) [arXiv:2407.07726]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    source="arXiv:2407.07726",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="gelu",
    tie_embeddings=True,
    num_image_tokens=256,
))
