"""h2o-danube-1.8b — H2O.ai Danube, llama+mistral mix with sliding-window
attention [arXiv:2401.16818]."""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    source="arXiv:2401.16818",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    swa_window=4096,
))
