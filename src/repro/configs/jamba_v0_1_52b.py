"""jamba-v0.1-52b — AI21 Jamba, Mamba+attention 1:7 interleave with MoE
(16 experts, top-2, MoE every other layer) [arXiv:2403.19887]."""
from repro.configs import register
from repro.configs.base import ATTN, MAMBA, ModelConfig

# 1 attention layer per 8-layer period (1:7 attn:mamba), MoE every 2 layers.
_PATTERN = (MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA)

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    source="arXiv:2403.19887",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    layer_pattern=_PATTERN,
    ssm_d_state=16,
    ssm_d_conv=4,
    ssm_expand=2,
))
