"""Kernel validation: XLA chunked paths and Pallas (interpret=True) against
the pure-jnp oracles, swept over shapes/dtypes, plus hypothesis properties.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

KEY = jax.random.PRNGKey(0)


def rn(i, *shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)
    return x.astype(dtype)


ATTN_SHAPES = [
    # b, sq, hq, hkv, d
    (1, 64, 2, 2, 16),        # MHA
    (2, 128, 4, 2, 32),       # GQA
    (2, 128, 4, 1, 32),       # MQA
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_xla_vs_ref(shape, dtype, window):
    b, sq, hq, hkv, d = shape
    q = rn(1, b, sq, hq, d, dtype=dtype)
    k = rn(2, b, sq, hkv, d, dtype=dtype)
    v = rn(3, b, sq, hkv, d, dtype=dtype)
    o1 = ops.flash_attention(q, k, v, causal=True, window=window,
                             q_block=32, kv_block=32)
    o2 = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_pallas_vs_ref(shape, dtype, window):
    b, sq, hq, hkv, d = shape
    q = rn(1, b, sq, hq, d, dtype=dtype)
    k = rn(2, b, sq, hkv, d, dtype=dtype)
    v = rn(3, b, sq, hkv, d, dtype=dtype)
    o1 = flash_attention_pallas(q, k, v, causal=True, window=window,
                                q_block=32, kv_block=32, interpret=True)
    o2 = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


def test_flash_masks():
    b, sq, hq, hkv, d = 2, 128, 4, 2, 16
    q, k, v = rn(1, b, sq, hq, d), rn(2, b, sq, hkv, d), rn(3, b, sq, hkv, d)
    kv_len = jnp.array([100, 128])
    kv_start = jnp.array([17, 0])
    for kw in ({"kv_len": kv_len}, {"kv_start": kv_start},
               {"kv_len": kv_len, "kv_start": kv_start}):
        o1 = ops.flash_attention(q, k, v, causal=True, q_block=32,
                                 kv_block=32, **kw)
        o2 = ref.attention_ref(q, k, v, causal=True, **kw)
        o3 = flash_attention_pallas(q, k, v, causal=True, q_block=32,
                                    kv_block=32, interpret=True, **kw)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
        np.testing.assert_allclose(np.asarray(o3), np.asarray(o2), atol=2e-5)


def test_flash_grad_vs_ref():
    b, sq, hq, hkv, d = 2, 96, 4, 2, 16
    q, k, v = rn(1, b, sq, hq, d), rn(2, b, sq, hkv, d), rn(3, b, sq, hkv, d)
    kv_len = jnp.array([80, 96])

    def f_flash(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, kv_len=kv_len,
                                    q_block=32, kv_block=32) * 0.01).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=True, kv_len=kv_len)
                .astype(jnp.float32) * 0.01).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


@pytest.mark.parametrize("skv,kvb", [(128, 32), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_pallas_vs_ref(skv, kvb, dtype):
    b, hq, hkv, d = 2, 4, 2, 32
    q = rn(1, b, 1, hq, d, dtype=dtype)
    k = rn(2, b, skv, hkv, d, dtype=dtype)
    v = rn(3, b, skv, hkv, d, dtype=dtype)
    kv_len = jnp.array([skv - 29, skv])
    o1 = decode_attention_pallas(q, k, v, kv_len=kv_len, kv_block=kvb,
                                 interpret=True)
    o2 = ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


@pytest.mark.parametrize("s,chunk,din,ds", [(64, 16, 16, 8), (100, 32, 8, 4)])
def test_ssm_xla_vs_ref(s, chunk, din, ds):
    b = 2
    x, dt = rn(1, b, s, din), jax.nn.softplus(rn(2, b, s, din))
    A = -jnp.exp(rn(3, din, ds) * 0.5)
    B, C, D = rn(4, b, s, ds), rn(5, b, s, ds), rn(6, din)
    h0 = rn(7, b, din, ds) * 0.1
    y1, h1 = ops.ssm_scan(x, dt, A, B, C, D, h0=h0, chunk=chunk)
    y2, h2 = ref.ssm_scan_ref(x, dt, A, B, C, D, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_pallas_vs_ref(dtype):
    b, s, din, ds = 2, 64, 16, 8
    x = rn(1, b, s, din, dtype=dtype)
    dt = jax.nn.softplus(rn(2, b, s, din)).astype(dtype)
    A = -jnp.exp(rn(3, din, ds) * 0.5)
    B, C, D = rn(4, b, s, ds), rn(5, b, s, ds), rn(6, din)
    y1, h1 = ssm_scan_pallas(x, dt, A, B, C, D, chunk=16, d_block=8,
                             interpret=True)
    y2, h2 = ref.ssm_scan_ref(x, dt, A, B, C, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=tol)


def test_ssm_grad_vs_ref():
    b, s, din, ds = 2, 48, 8, 4
    x, dt = rn(1, b, s, din), jax.nn.softplus(rn(2, b, s, din))
    A = -jnp.exp(rn(3, din, ds) * 0.5)
    B, C, D = rn(4, b, s, ds), rn(5, b, s, ds), rn(6, din)

    def f(impl):
        def loss(x, dt, A, B, C, D):
            y, h = impl(x, dt, A, B, C, D)
            return (y * 0.01).sum() + (h * 0.02).sum()
        return loss

    g1 = jax.grad(f(lambda *a: ops.ssm_scan(*a, chunk=16)),
                  argnums=tuple(range(6)))(x, dt, A, B, C, D)
    g2 = jax.grad(f(ref.ssm_scan_ref), argnums=tuple(range(6)))(
        x, dt, A, B, C, D)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_mlstm_chunked_vs_ref():
    b, s, h, dk, dv = 2, 96, 4, 16, 24
    q, k = rn(1, b, s, h, dk), rn(2, b, s, h, dk)
    v = rn(3, b, s, h, dv)
    ig = jax.nn.sigmoid(rn(4, b, s, h))
    fg = jax.nn.sigmoid(rn(5, b, s, h) + 2)
    y1, (C1, n1) = ops.mlstm_scan(q, k, v, ig, fg, chunk=16)
    y2, (C2, n2) = ref.mlstm_scan_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-4)


def test_decode_steps_match_scan():
    """ssm/mlstm single-step chains == chunked scan prefix."""
    b, s, din, ds = 2, 12, 8, 4
    x, dt = rn(1, b, s, din), jax.nn.softplus(rn(2, b, s, din))
    A = -jnp.exp(rn(3, din, ds) * 0.5)
    B, C, D = rn(4, b, s, ds), rn(5, b, s, ds), rn(6, din)
    y_ref, _ = ref.ssm_scan_ref(x, dt, A, B, C, D)
    h = jnp.zeros((b, din, ds))
    for t in range(s):
        y, h = ops.ssm_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], D, h)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref[:, t]),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# hypothesis: online softmax == softmax for arbitrary block splits
# (skipped, not failed, when hypothesis is unavailable)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8), st.data())
def test_online_softmax_invariant(n, nblocks, data):
    xs = data.draw(st.lists(
        st.floats(-30, 30, allow_nan=False), min_size=n, max_size=n))
    x = np.asarray(xs, np.float32)
    # reference
    p_ref = np.exp(x - x.max())
    p_ref /= p_ref.sum()
    # online over nblocks pieces
    m, l, acc = -np.inf, 0.0, np.zeros_like(x)
    bounds = np.linspace(0, n, nblocks + 1).astype(int)
    for i in range(nblocks):
        blk = x[bounds[i]:bounds[i + 1]]
        if len(blk) == 0:
            continue
        m_new = max(m, blk.max())
        corr = np.exp(m - m_new) if np.isfinite(m) else 0.0
        l = l * corr + np.exp(blk - m_new).sum()
        acc *= corr
        acc[bounds[i]:bounds[i + 1]] = np.exp(blk - m_new)
        m = m_new
    np.testing.assert_allclose(acc / l, p_ref, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(4, 32), st.integers(2, 6),
       st.integers(1, 4))
def test_ssm_chunk_invariance(b, s, din, ds):
    """Chunked scan result is independent of the chunk size (property)."""
    x, dt = rn(1, b, s, din), jax.nn.softplus(rn(2, b, s, din))
    A = -jnp.exp(rn(3, din, ds) * 0.5)
    B, C, D = rn(4, b, s, ds), rn(5, b, s, ds), rn(6, din)
    outs = []
    for chunk in (1, 2, s):
        y, h = ops.ssm_scan(x, dt, A, B, C, D, chunk=chunk)
        outs.append((np.asarray(y), np.asarray(h)))
    for y, h in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], atol=1e-4)
        np.testing.assert_allclose(h, outs[0][1], atol=1e-4)


def test_backend_context_manager_restores_on_error():
    """`with ops.backend(...)` must restore the global backend even when
    the body raises — the try/finally dance it replaces leaked state."""
    assert ops.get_backend() == "xla"
    with ops.backend("pallas_interpret"):
        assert ops.get_backend() == "pallas_interpret"
        with ops.backend("pallas"):           # nests, restores one level
            assert ops.get_backend() == "pallas"
        assert ops.get_backend() == "pallas_interpret"
    assert ops.get_backend() == "xla"
    with pytest.raises(RuntimeError):
        with ops.backend("pallas_interpret"):
            raise RuntimeError("boom")
    assert ops.get_backend() == "xla"
