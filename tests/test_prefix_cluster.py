"""Cluster-scale KV: host page tier, shared prefix directory, and
prefix-aware routing.

Correctness bar (same as every serving feature): tiers and the directory
change WHERE pages come from — device pool, host spill, a peer replica —
never what gets generated. Outputs stay token-identical to cold serving;
tier bookkeeping is checked against an independent model under randomized
demote/promote/fetch interleavings (every page in exactly one tier,
refcounts conserved, the directory never pointing at a freed page)."""
import jax
import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core.genetic import choose_host_tiers, search
from repro.models import model as M
from repro.serving.block_manager import (BlockPool, BlockTable,
                                         HostPagePool, PrefixIndex,
                                         chunk_hashes)
from repro.serving.cluster_kv import (ClusterPrefixDirectory,
                                      wire_cluster_prefix)
from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request
from repro.serving.router import Router

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Satellite: partial-prefix re-hit must refresh LRU order
# ---------------------------------------------------------------------------

def test_partial_prefix_rehit_refreshes_lru_order():
    """A chain whose short head keeps hitting must not be evicted
    wholesale: re-acquiring the head refreshes ITS recency, so eviction
    trims the cold deep tail (and colder chains) first."""
    pool = BlockPool(8, block_size=4)
    ix = PrefixIndex(pool)
    evicted = []
    ix.spill = lambda h, bid: evicted.append(h)

    hs_a = chunk_hashes(np.arange(12, dtype=np.int32), 4)          # 3 blocks
    ta = BlockTable(pool)
    assert ta.allocate_tokens(12)
    ix.register(hs_a, ta.blocks)
    hs_b = chunk_hashes(100 + np.arange(8, dtype=np.int32), 4)     # 2 blocks
    tb = BlockTable(pool)
    assert tb.allocate_tokens(8)
    ix.register(hs_b, tb.blocks)
    ta.release()
    tb.release()

    # the short head of chain A keeps hitting
    t = BlockTable(pool, ix.acquire(hs_a[:1]))
    t.release()

    # pressure for two blocks: A's cold DEEP TAIL goes (deepest first —
    # a chained hash only matches head-first, so evicting the head would
    # orphan the whole chain), the re-hit head and chain B survive
    assert ix.evict(2) == 2
    assert evicted == [hs_a[2], hs_a[1]]
    assert ix.match_len(hs_a) == 1          # head still serves
    assert ix.match_len(hs_b) == 2          # untouched chain intact

    # next pressure takes the colder chain B tail before A's hot head
    assert ix.evict(1) == 1
    assert evicted[-1] == hs_b[1]
    assert ix.match_len(hs_a) == 1


def test_register_orders_chain_tail_first_for_eviction():
    """Freshly registered chains evict tail-first even without re-hits."""
    pool = BlockPool(6, block_size=4)
    ix = PrefixIndex(pool)
    order = []
    ix.spill = lambda h, bid: order.append(h)
    hs = chunk_hashes(np.arange(16, dtype=np.int32), 4)            # 4 blocks
    t = BlockTable(pool)
    assert t.allocate_tokens(16)
    ix.register(hs, t.blocks)
    t.release()
    assert ix.evict(3) == 3
    assert order == [hs[3], hs[2], hs[1]]
    assert ix.match_len(hs) == 1


# ---------------------------------------------------------------------------
# HostPagePool unit behavior
# ---------------------------------------------------------------------------

def test_host_pool_put_get_one_tier_and_lru_bound():
    hp = HostPagePool(2, block_size=4)
    dropped = []
    hp.on_evict = dropped.append
    hp.put(1, [{"k": np.ones(2)}])
    hp.put(2, [{"k": np.ones(2) * 2}])
    assert hp.match_len([1, 2, 3]) == 2
    # get POPS: the payload lives in exactly one tier
    p = hp.get(1)
    assert p is not None and 1 not in hp
    assert hp.promotions == 1
    # peek does not promote (cluster export ships a copy)
    assert hp.peek(2) is not None and 2 in hp
    # over capacity: LRU drop fires on_evict
    hp.put(3, [{"k": np.zeros(2)}])
    hp.put(4, [{"k": np.zeros(2)}])
    assert dropped == [2] and hp.evictions == 1
    # restore is counter-neutral (a failed promotion never happened)
    d, pr = hp.demotions, hp.promotions
    q = hp.get(3)
    hp.restore(3, q)
    assert (hp.demotions, hp.promotions) == (d, pr)
    assert 3 in hp


# ---------------------------------------------------------------------------
# ClusterPrefixDirectory unit behavior
# ---------------------------------------------------------------------------

def test_directory_publish_holders_resident_blocks():
    d = ClusterPrefixDirectory()
    d.publish(7, 0, "host")
    d.publish(7, 1, "device")
    d.publish(7, 2, "device")
    # device tier first (no swap-in on export), then lowest replica id
    assert d.holders(7) == [(1, "device"), (2, "device"), (0, "host")]
    assert d.holders(7, exclude=1) == [(2, "device"), (0, "host")]
    # re-publish moves tiers; unpublish drops the claim entirely
    d.publish(1, 0, "device")
    d.publish(2, 0, "host")
    # chain walk stops at the first gap: hash 3 unpublished
    assert d.resident_blocks([1, 2, 3, 7], 0) == (1, 1)
    d.unpublish(2, 0)
    assert d.resident_blocks([1, 2, 3, 7], 0) == (1, 0)
    d.unpublish(7, 0)
    d.unpublish(7, 1)
    d.unpublish(7, 2)
    assert d.tier(7, 2) is None


# ---------------------------------------------------------------------------
# Property: tier invariants under demote/promote/fetch interleavings
# ---------------------------------------------------------------------------

class _Rep:
    """One replica's tier stack in miniature, wired exactly like
    PagedPipelineBatcher: eviction spills to the host pool and publishes
    "host"; the host LRU drop unpublishes; registration publishes
    "device" and discards any stale host copy."""

    def __init__(self, rid, directory, n_usable, block_size, host_cap):
        self.rid = rid
        self.d = directory
        self.pool = BlockPool(n_usable + 1, block_size)
        self.ix = PrefixIndex(self.pool)
        self.host = HostPagePool(host_cap, block_size)
        self.tables = []

        def spill(h, bid):
            self.host.put(h, {"blk": int(bid)})
            self.d.publish(h, self.rid, "host")
        self.ix.spill = spill
        self.host.on_evict = lambda h: self.d.unpublish(h, self.rid)


def _admit(rep, peers, prompt, block_size):
    """Mirror of _match_slot's tier materialization: alias the device
    match, then per missing block promote from host (pop BEFORE alloc)
    or fetch from a peer, register + adopt, publish."""
    hs = chunk_hashes(prompt, block_size)
    L = rep.ix.match_len(hs)
    t = BlockTable(rep.pool, rep.ix.acquire(hs[:L]))
    for h in hs[L:]:
        pay, src = rep.host.get(h), "host"
        if pay is None:
            src = None
            for peer in peers:
                if peer.ix.lookup(h) is not None \
                        or peer.host.peek(h) is not None:
                    pay, src = {"blk": -1}, "fetch"
                    break
        if pay is None:
            break
        if rep.pool.n_free < 1:
            rep.ix.evict(1)
        blks = rep.pool.alloc(1)
        if blks is None:
            if src == "host":
                rep.host.restore(h, pay)
            break
        rep.ix.register([h], blks)
        t.adopt(blks)
        rep.host.discard(h)
        rep.d.publish(h, rep.rid, "device")
    n_have = t.n_blocks * block_size
    if len(prompt) > n_have and not t.ensure(len(prompt) - 1):
        rep.ix.evict(len(prompt) // block_size + 1)
        if not t.ensure(len(prompt) - 1):
            t.release()
            return
    k = min(len(hs), t.n_blocks)
    rep.ix.register(hs[:k], t.blocks[:k])
    for h in hs[:k]:
        rep.host.discard(h)
        rep.d.publish(h, rep.rid, "device")
    rep.tables.append(t)


def _check_invariants(reps, directory):
    for rep in reps:
        # refcount conservation: pool refs == table holds + index holds
        holds = np.zeros(rep.pool.n_blocks, np.int64)
        for t in rep.tables:
            for b in t.blocks:
                holds[b] += 1
        for b in rep.ix._lru:
            holds[b] += 1
        for b in range(1, rep.pool.n_blocks):
            assert rep.pool.ref(b) == holds[b], (rep.rid, b)
        # every page in exactly one tier
        assert not set(rep.ix._block_of) & set(rep.host._pages), rep.rid
        # host tier honors its capacity bound
        assert len(rep.host) <= rep.host.capacity
    # directory residency never points at a freed/absent page
    for h, m in directory._res.items():
        for rid, tier in m.items():
            rep = reps[rid]
            if tier == "device":
                assert rep.ix.lookup(h) is not None, (h, rid)
            else:
                assert h in rep.host, (h, rid)


def _run_tier_interleaving(seed, n_ops=40):
    rng = np.random.RandomState(seed % (2 ** 31))
    bs = 4
    d = ClusterPrefixDirectory()
    reps = [_Rep(0, d, 5, bs, 3), _Rep(1, d, 7, bs, 2)]
    for _ in range(n_ops):
        rep = reps[rng.randint(len(reps))]
        peers = [r for r in reps if r is not rep]
        op = rng.randint(4)
        if op == 0:                     # admit from a tiny alphabet
            n_tok = rng.randint(1, 3 * bs + 2)
            _admit(rep, peers, rng.randint(0, 3, size=n_tok), bs)
        elif op == 1 and rep.tables:    # finish a request
            rep.tables.pop(rng.randint(len(rep.tables))).release()
        elif op == 2:                   # eviction pressure -> demotions
            rep.ix.evict(rng.randint(1, 3))
        else:                           # host churn via repeat admits
            n_tok = rng.randint(bs, 2 * bs + 1)
            _admit(rep, peers, rng.randint(0, 2, size=n_tok), bs)
        _check_invariants(reps, d)
    for rep in reps:
        for t in rep.tables:
            t.release()
        rep.tables = []
    _check_invariants(reps, d)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_tier_invariants_property(seed):
    _run_tier_interleaving(seed)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_tier_invariants_seeded(seed):
    """Always-run fallback for environments without hypothesis."""
    _run_tier_interleaving(seed * 7919 + 13)


# ---------------------------------------------------------------------------
# Satellite: routing determinism + prefix-aware dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def two_replica_router_parts():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def mk_router(**kw):
        reps = [AsymmetricPipeline(cfg, params, [L], [[dev]])
                for _ in range(2)]
        base = dict(n_slots=2, max_len=48, cache_layout="paged",
                    block_size=8, prefix_caching=True)
        base.update(kw)
        return Router(reps, **base)

    return cfg, mk_router


def test_router_tiebreak_deterministic_lowest_replica_id(
        two_replica_router_parts):
    cfg, mk_router = two_replica_router_parts
    r = mk_router()
    req = Request(rid=0, prompt=np.arange(9, dtype=np.int32),
                  max_new_tokens=2, arrival=0.0)
    # idle workers tie on load: lowest replica id wins, in EITHER order
    w = r._dispatch(list(r.workers), req, 0.0)
    assert w.replica_id == 0
    w = r._dispatch(list(reversed(r.workers)), req, 0.0)
    assert w.replica_id == 0


def test_router_seeded_tiebreak_reproducible(two_replica_router_parts):
    cfg, mk_router = two_replica_router_parts
    req = Request(rid=0, prompt=np.arange(9, dtype=np.int32),
                  max_new_tokens=2, arrival=0.0)
    picks = []
    for _ in range(2):
        r = mk_router(route_seed=123)
        picks.append([r._dispatch(list(r.workers), req, 0.0).replica_id
                      for _ in range(12)])
    assert picks[0] == picks[1]          # same seed, same route sequence


def test_router_prefix_aware_dispatch_prefers_resident_replica(
        two_replica_router_parts):
    cfg, mk_router = two_replica_router_parts
    r = mk_router(cluster_prefix=True)
    assert r.cluster_dir is not None
    prompt = np.arange(24, dtype=np.int32)
    for h in chunk_hashes(prompt, 8):
        r.cluster_dir.publish(h, 1, "device")
    req = Request(rid=0, prompt=prompt, max_new_tokens=2, arrival=0.0)
    # equal load, but replica 1 holds the whole prefix: affinity wins
    assert r._dispatch(list(r.workers), req, 0.0).replica_id == 1
    # host-resident blocks count at a discount but still attract
    for h in chunk_hashes(prompt, 8):
        r.cluster_dir.publish(h, 1, "host")
    assert r._dispatch(list(r.workers), req, 0.0).replica_id == 1
    # weight 0 restores pure least-loaded + deterministic tiebreak
    r.prefix_route_weight = 0.0
    assert r._dispatch(list(r.workers), req, 0.0).replica_id == 0


# ---------------------------------------------------------------------------
# End-to-end: host-tier spill/promotion and cluster fetch are invisible
# to the token stream
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cluster_served_cold():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    def mk_reqs():
        reqs = []
        for i in range(6):
            rng = np.random.RandomState(100 + i % 3)   # 3 prompt families
            prompt = rng.randint(0, cfg.vocab_size, size=24).astype(np.int32)
            reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=4,
                                arrival=0.3 * i))
        return reqs

    reqs_c = mk_reqs()
    PipelineBatcher(pipe(), n_slots=2, max_len=48).serve(reqs_c,
                                                         deadline=1e9)
    return cfg, params, pipe, mk_reqs, reqs_c


def test_host_tier_promotion_bit_identical(cluster_served_cold):
    """Device pools too small for three 24-token families: evictions
    demote to the host tier and revisits promote back instead of
    re-prefilling — with the token streams unchanged."""
    cfg, params, pipe, mk_reqs, reqs_c = cluster_served_cold
    reqs_h = mk_reqs()
    w = PagedPipelineBatcher(pipe(), n_slots=2, max_len=48, block_size=8,
                             stage_blocks=[10, 10], prefix_caching=True,
                             host_blocks=64, host_swap_cost=0.05,
                             prefill_token_cost=0.125)
    stats = run_serve_loop([w], reqs_h, deadline=1e9, clock=VirtualClock())
    for rc, rh in zip(reqs_c, reqs_h):
        assert list(rc.output) == list(rh.output), rc.rid
    assert stats.host_demotions > 0
    assert stats.host_promotions > 0
    assert stats.host_hit_tokens > 0
    assert "host=" in stats.summary()


def test_cluster_prefix_fetch_bit_identical(cluster_served_cold):
    """Two replicas behind a shared directory: a prompt landing on the
    replica that never saw its family fetches the prefix pages from the
    peer instead of cold-prefilling — token streams unchanged."""
    cfg, params, pipe, mk_reqs, reqs_c = cluster_served_cold
    reqs_x = mk_reqs()
    ws = [PagedPipelineBatcher(pipe(), n_slots=2, max_len=48, block_size=8,
                               prefix_caching=True, replica_id=i,
                               prefill_token_cost=0.125)
          for i in range(2)]
    directory = wire_cluster_prefix(ws)
    stats = run_serve_loop(ws, reqs_x, deadline=1e9, clock=VirtualClock())
    for rc, rx in zip(reqs_c, reqs_x):
        assert list(rc.output) == list(rx.output), rc.rid
    assert stats.prefix_fetches > 0
    assert stats.prefix_fetched_bytes > 0
    assert len(directory) > 0
    assert "fetch=" in stats.summary()


def test_preempt_recovery_consults_host_tier(cluster_served_cold):
    """Preemption's truncated blocks land in the index; the pressure that
    caused it demotes them to the host tier, and the re-admitted request
    PROMOTES instead of re-prefilling — outputs still cold-identical."""
    cfg, params, pipe, mk_reqs, reqs_c = cluster_served_cold
    reqs_p = mk_reqs()
    # pools tight enough that decode growth forces preemption
    w = PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             stage_blocks=[9, 9], prefix_caching=True,
                             host_blocks=64, prefill_token_cost=0.125)
    stats = run_serve_loop([w], reqs_p, deadline=1e9, clock=VirtualClock())
    for rc, rp in zip(reqs_c, reqs_p):
        assert list(rc.output) == list(rp.output), rc.rid
    assert stats.host_promotions > 0


# ---------------------------------------------------------------------------
# Scheduler layer: host-tier sizing and residency-derived hit rates
# ---------------------------------------------------------------------------

def test_effective_prefix_hit_rate_model():
    # no working set: the static scalar stands
    assert cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=0, device_blocks=0) == 0.6
    # full device coverage: shareable fraction achieved outright
    assert cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=100, device_blocks=100) == 0.6
    # half coverage halves the rate
    assert cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=100, device_blocks=50) \
        == pytest.approx(0.3)
    # host blocks extend reach, discounted by swap cost
    lo = cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=100, device_blocks=50)
    hi = cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=100, device_blocks=50, host_blocks=50,
        tier_discount=0.5)
    assert lo < hi < 0.6
    # a swap as dear as recompute makes the tier worthless
    assert cm.effective_prefix_hit_rate(
        0.6, working_set_blocks=100, device_blocks=50, host_blocks=500,
        tier_discount=1.0) == pytest.approx(0.3)


def test_host_tier_block_arithmetic():
    cfg = get_config("granite-8b")
    prof = cm.ModelProfile.from_config(cfg)
    task = cm.Task(batch=1, s_in=96, s_out=16)
    blk = cm.kv_block_bytes(prof, task, 16)
    assert blk > 0
    assert cm.host_tier_blocks(10 * blk, prof, task, 16) == 10
    # quantized pools spill at their narrow width: more blocks per byte
    assert cm.host_tier_blocks(10 * blk, prof, task, 16, kv_dtype="int8") \
        > 10
    assert cm.host_swap_seconds_per_block(prof, task, 16, 0.0) == 0.0
    s = cm.host_swap_seconds_per_block(prof, task, 16, 8.0)
    assert s == pytest.approx(cm.kv_block_bytes(prof, task, 16) / 1e9)


def test_choose_host_tiers_targets_deficit_replicas():
    class P:                           # plan stub: only .cost is read
        def __init__(self, cost):
            self.cost = cost

    plans = [P(1.0), P(1.0)]
    caps = {id(plans[0]): 100, id(plans[1]): 1}   # replica 1 is starved
    out = choose_host_tiers(plans, lambda p: caps[id(p)], rate=20.0,
                            blocks_per_seq=4, budget_blocks=90)
    assert out[1] > out[0] == 0        # the small-HBM replica gets it all
    # no deficit anywhere: the budget still backs prefix churn, evenly
    out = choose_host_tiers(plans, lambda p: 1000, rate=1.0,
                            blocks_per_seq=4, budget_blocks=7)
    assert out == [4, 3]
    assert choose_host_tiers([], lambda p: 0, rate=1.0,
                             blocks_per_seq=4, budget_blocks=7) == []


def test_search_places_host_tier(monkeypatch):
    pool = cl.case_study_cluster()
    cfg = get_config("h2o-danube-1.8b")
    prof = cm.ModelProfile.from_config(cfg)
    task = cm.Task(batch=1, s_in=96, s_out=16)
    res = search(pool, prof, task, deadline=30.0, rate=2.0, iters=2,
                 seed=0, kv_block_size=16, prefix_hit_rate=0.6,
                 prefix_working_set=4096, host_tier_bytes=4e9,
                 host_swap_gbps=32.0, cluster_prefix=True)
    assert res.host_blocks is not None
    assert len(res.host_blocks) == len(res.assignment.pipelines)
    assert sum(res.host_blocks) > 0
    # without the knob the dimension stays out of the result
    res2 = search(pool, prof, task, deadline=30.0, rate=2.0, iters=1,
                  seed=0, kv_block_size=16)
    assert res2.host_blocks is None
