"""Paged KV cache: block pool/table invariants, paged-vs-contiguous
gather/scatter round-trips, the Pallas paged-decode kernel, and end-to-end
bit-identity of paged serving against contiguous serving (including under
preemption-by-recompute). The correctness bar for the whole refactor is
BIT-identity: the paged layout must change where cache bytes live, never
what attention computes."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.models import model as M
from repro.serving.block_manager import (BlockPool, BlockTable, NULL_BLOCK,
                                         blocks_for_tokens)
from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request

KEY = jax.random.PRNGKey(0)


def rn(i, *shape):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Block pool / table bookkeeping
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_refcount():
    pool = BlockPool(6, block_size=8)        # 5 usable + null
    assert pool.n_free == 5
    got = pool.alloc(3)
    assert got is not None and len(set(got)) == 3 and NULL_BLOCK not in got
    assert pool.n_free == 2 and pool.n_used == 3
    assert pool.alloc(3) is None             # all-or-nothing
    assert pool.n_free == 2                  # failed alloc took nothing
    pool.incref(got[0])                      # prefix-sharing style alias
    pool.free(got[0])
    assert pool.n_free == 2                  # still referenced
    pool.free(got[0])
    assert pool.n_free == 3                  # now returned
    for b in got[1:]:
        pool.free(b)
    assert pool.n_free == 5


def test_block_table_grow_release():
    pool = BlockPool(5, block_size=4)
    t = BlockTable(pool)
    assert t.allocate_tokens(9)              # 3 blocks
    assert t.n_blocks == 3 and pool.n_free == 1
    assert t.ensure(10)                      # pos 10 -> 3 blocks, no growth
    assert t.n_blocks == 3
    assert t.ensure(12)                      # pos 12 -> 4th block
    assert t.n_blocks == 4 and pool.n_free == 0
    assert not t.ensure(16)                  # pool dry
    arr = t.as_array(6)
    assert arr.shape == (6,) and (arr[4:] == NULL_BLOCK).all()
    t.release()
    assert pool.n_free == 4 and t.n_blocks == 0


def test_block_table_fork_refcounts():
    pool = BlockPool(4, block_size=2)
    t = BlockTable(pool)
    assert t.allocate_tokens(4)
    f = t.fork()
    assert f.blocks == t.blocks
    t.release()
    assert pool.n_free == 1                  # fork still holds them
    f.release()
    assert pool.n_free == 3


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 24), st.integers(1, 8),
       st.integers(0, 10 ** 6))
def test_block_table_roundtrip_property(n_seqs, max_tokens, block_size, seed):
    """Property: scatter-to-pages then gather-through-tables reproduces the
    contiguous scatter_cache_rows layout for any (seqs, lengths, block
    size) — BlockTable gather/scatter and the contiguous path agree."""
    rng = np.random.RandomState(seed % (2 ** 31))
    lens = rng.randint(1, max_tokens + 1, size=n_seqs)
    max_blocks = blocks_for_tokens(max_tokens, block_size)
    S = max_blocks * block_size
    pool = BlockPool(1 + n_seqs * max_blocks, block_size)
    tables = []
    for L in lens:
        t = BlockTable(pool)
        assert t.allocate_tokens(int(L))
        tables.append(t)
    h, d = 2, 4
    rows = {"k": jnp.asarray(rng.randn(n_seqs, S, h, d), jnp.float32)}
    # contiguous: rows scattered into a slot pool, read back directly
    contig = M.scatter_cache_rows(
        {"k": jnp.zeros((n_seqs, S, h, d), jnp.float32)}, rows,
        list(range(n_seqs)))
    # paged: rows scattered into pages, gathered back through the tables
    dest = np.stack([t.as_array(max_blocks) for t in tables]).reshape(-1)
    pages = M.scatter_rows_to_pages(
        {"k": jnp.zeros((pool.n_blocks, block_size, h, d), jnp.float32)},
        rows, dest)
    bt = jnp.asarray(np.stack([t.as_array(max_blocks) for t in tables]))
    back = ref.gather_pages(pages["k"], bt)
    for i, L in enumerate(lens):
        # identical within the valid prefix; beyond it the null page
        # absorbs the padding (masked by kv_len everywhere it matters)
        nb = blocks_for_tokens(int(L), block_size)
        np.testing.assert_array_equal(
            np.asarray(back[i, :nb * block_size]),
            np.asarray(contig["k"][i, :nb * block_size]))


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("skv,kvb", [(100, 32), (129, 64)])
def test_decode_pallas_ragged_last_block(skv, kvb):
    """Satellite: skv need not divide kv_block — the final block is padded
    and masked instead of asserted away."""
    b, hq, hkv, d = 2, 4, 2, 32
    q = rn(1, b, 1, hq, d)
    k = rn(2, b, skv, hkv, d)
    v = rn(3, b, skv, hkv, d)
    kv_len = jnp.array([skv - 13, skv])
    o1 = decode_attention_pallas(q, k, v, kv_len=kv_len, kv_block=kvb,
                                 interpret=True)
    o2 = ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    # and with no kv_len at all
    o3 = decode_attention_pallas(q, k, v, kv_block=kvb, interpret=True)
    o4 = ref.decode_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o3), np.asarray(o4), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_vs_ref(dtype):
    b, hq, hkv, d = 2, 4, 2, 32
    bs, n_blocks, nb = 16, 16, 5
    q = rn(1, b, 1, hq, d).astype(dtype)
    kp = rn(2, n_blocks, bs, hkv, d).astype(dtype)
    vp = rn(3, n_blocks, bs, hkv, d).astype(dtype)
    bt = jnp.asarray(
        np.array([[3, 1, 4, 0, 0], [5, 9, 2, 6, 8]], np.int32))
    kv_len = jnp.array([41, 80])             # ragged + full tables
    o1 = paged_decode_attention_pallas(q, kp, vp, bt, kv_len=kv_len,
                                       interpret=True)
    o2 = ref.paged_decode_attention_ref(q, kp, vp, bt, kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


def test_paged_ops_xla_bit_identical_to_contiguous():
    """The ops.paged_decode_attention XLA path must be BITWISE equal to
    contiguous decode on the gathered cache (same shapes, same HLO)."""
    b, hq, hkv, d = 2, 4, 2, 16
    bs, n_blocks, nb = 8, 12, 4
    q = rn(1, b, 1, hq, d)
    kp = rn(2, n_blocks, bs, hkv, d)
    vp = rn(3, n_blocks, bs, hkv, d)
    bt = jnp.asarray(np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32))
    kv_len = jnp.array([19, 32])
    o_paged = ops.paged_decode_attention(q, kp, vp, bt, kv_len=kv_len)
    o_contig = ops.decode_attention(q, ref.gather_pages(kp, bt),
                                    ref.gather_pages(vp, bt), kv_len=kv_len)
    assert np.array_equal(np.asarray(o_paged), np.asarray(o_contig))


# ---------------------------------------------------------------------------
# Model-level bit-identity (monolithic decode_step_paged)
# ---------------------------------------------------------------------------

def test_decode_step_paged_bit_identical():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    n_slots, slot_len, bs = 2, 32, 8
    nbmax = slot_len // bs
    lens = np.array([5, 9], np.int32)
    toks = np.zeros((n_slots, 16), np.int32)
    for i in range(n_slots):
        toks[i, :lens[i]] = rng.randint(0, cfg.vocab_size, lens[i])

    scratch = M.init_cache(cfg, n_slots, slot_len)
    lg, scratch = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                            scratch, lens=jnp.asarray(lens))
    pool_c = M.scatter_cache_rows(M.init_cache(cfg, n_slots, slot_len),
                                  scratch, [0, 1], batch_axis=1)
    bt = (1 + np.arange(n_slots * nbmax, dtype=np.int32)
          ).reshape(n_slots, nbmax)
    pool_p = {
        k: M.scatter_cache_rows_paged(
            M.init_paged_cache(cfg, 1 + n_slots * nbmax, bs, n_slots)[k],
            scratch[k], [0, 1], bt.reshape(-1), batch_axis=1)
        for k in scratch}

    pos = lens.copy()
    lg_c = lg_p = np.asarray(lg)
    for step in range(6):
        nxt = jnp.asarray(np.argmax(lg_c, -1).astype(np.int32))
        lg_c, pool_c = M.decode_step(cfg, params, nxt, pool_c,
                                     jnp.asarray(pos))
        nxt_p = jnp.asarray(np.argmax(lg_p, -1).astype(np.int32))
        lg_p, pool_p = M.decode_step_paged(cfg, params, nxt_p, pool_p,
                                           jnp.asarray(pos),
                                           jnp.asarray(bt))
        lg_c, lg_p = np.asarray(lg_c), np.asarray(lg_p)
        assert np.array_equal(lg_c, lg_p), f"step {step} diverged"
        pos += 1


# ---------------------------------------------------------------------------
# End-to-end: paged serving == contiguous serving on a 2-stage pipeline
# ---------------------------------------------------------------------------

def _mk_reqs(cfg, *, n=4, max_new=5, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=5 + 3 * i).astype(np.int32),
                    max_new_tokens=max_new, arrival=0.02 * i)
            for i in range(n)]


def _pipe(cfg, params):
    dev = jax.devices()[0]
    L = cfg.num_layers
    return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-v0.1-52b"])
def test_pipeline_paged_equals_contiguous(arch):
    """Tentpole gate: on a 2-stage asymmetric pipeline, paged serving must
    produce the same tokens as contiguous serving for every request —
    including hybrid stacks where recurrent layers keep O(1) slot states
    while attention layers page."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    reqs_c = _mk_reqs(cfg)
    PipelineBatcher(_pipe(cfg, params), n_slots=3,
                    max_len=48).serve(reqs_c, deadline=1e9)
    reqs_p = _mk_reqs(cfg)
    stats = PagedPipelineBatcher(_pipe(cfg, params), n_slots=3, max_len=48,
                                 block_size=8).serve(reqs_p, deadline=1e9)
    assert stats.preemptions == 0            # full-occupancy pool
    for rc, rp in zip(reqs_c, reqs_p):
        assert list(rc.output) == list(rp.output), rc.rid


def test_paged_preemption_recomputes_identically():
    """A pool too small for all slots' full generations forces
    preempt-by-recompute; the evicted requests still finish with exactly
    the tokens contiguous serving produces."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)

    def reqs(seed=1):
        rng = np.random.RandomState(seed)
        return [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           size=6).astype(np.int32),
                        max_new_tokens=20, arrival=0.0) for i in range(3)]

    reqs_c = reqs()
    PipelineBatcher(_pipe(cfg, params), n_slots=3,
                    max_len=32).serve(reqs_c, deadline=1e9)
    # each request ends at 26 tokens = 4 blocks of 8; three concurrent
    # need 12 blocks but the pools hold 8 usable -> eviction mid-decode
    reqs_p = reqs()
    stats = PagedPipelineBatcher(
        _pipe(cfg, params), n_slots=3, max_len=32, block_size=8,
        stage_blocks=[9, 9], admit_headroom=2).serve(reqs_p, deadline=1e9)
    assert stats.preemptions > 0
    for rc, rp in zip(reqs_c, reqs_p):
        assert list(rc.output) == list(rp.output), rc.rid


def test_oversized_request_rejected_and_counted():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    reqs = _mk_reqs(cfg, n=2) + [
        Request(rid=99, prompt=np.arange(40, dtype=np.int32),
                max_new_tokens=20, arrival=0.0)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        stats = PagedPipelineBatcher(
            _pipe(cfg, params), n_slots=2, max_len=32,
            block_size=8).serve(reqs, deadline=1e9)
    assert stats.rejected == 1
    assert len(reqs[-1].output) == 0
    for r in reqs[:2]:
        assert len(r.output) == r.max_new_tokens
    # a rejected request served nobody: it cannot count as SLO-attained
    assert stats.attainment == pytest.approx(2 / 3)


def test_search_kv_capacity_bound():
    """kv_block_size threads cost_model.concurrent_capacity into the
    genetic search's simulated replicas: bounding capacity can only lower
    simulated attainment."""
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    from repro.core.genetic import Evaluator
    from repro.core.plan import PipelinePlan, StagePlan
    task = cm.Task(batch=1, s_in=128, s_out=64)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    c = cl.case_study_cluster()
    # the paper's feasible case-study layout: [4,2,2] GPUs / 48-20-12 layers
    plan = PipelinePlan([StagePlan([0, 1, 2, 3], 48), StagePlan([4, 5], 20),
                        StagePlan([6, 7], 12)], cost=1.0, bottleneck=0.2)
    ev_ideal = Evaluator(c, prof, task, deadline=3.0, rate=4.0)
    ev_paged = Evaluator(c, prof, task, deadline=3.0, rate=4.0,
                         kv_block_size=16)
    assert ev_ideal._max_concurrent(plan) == 0            # unbounded
    mc = ev_paged._max_concurrent(plan)
    assert mc > 0
    # the bound is the TIGHTEST stage's capacity
    assert mc == min(
        cm.concurrent_capacity(c, st.device_ids, st.num_layers, prof,
                               task, block_size=16)
        for st in plan.stages)


# ---------------------------------------------------------------------------
# Scheduler-side block accounting
# ---------------------------------------------------------------------------

def test_cost_model_block_granularity():
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    task = cm.Task(batch=1, s_in=128, s_out=64)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    c = cl.case_study_cluster()
    devs = [0, 1, 2, 3]
    # paged rounds actual usage UP to whole blocks...
    m0 = cm.mem_bytes_per_device(c, devs, 48, prof, task)
    m1 = cm.mem_bytes_per_device(c, devs, 48, prof, task, block_size=24)
    assert m1 >= m0
    # ...but capacity planning no longer reserves worst-case rows: far
    # more concurrent sequences fit in the same memory
    contig = cm.concurrent_capacity(c, devs, 48, prof, task, max_len=2048)
    paged = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16)
    assert paged >= 2 * contig


def test_slo_sim_reflects_paged_capacity():
    from repro.core.slo_sim import ReplicaModel, simulate
    kw = dict(rate=4.0, deadline=3.0, duration=30.0)
    tight = simulate([ReplicaModel(1.0, 0.2, max_concurrent=1)], **kw)
    roomy = simulate([ReplicaModel(1.0, 0.2, max_concurrent=8)], **kw)
    free = simulate([ReplicaModel(1.0, 0.2)], **kw)
    assert tight < roomy <= free


# ---------------------------------------------------------------------------
# Quantized KV pages (int8/fp8 payload pools + per-token-per-head scales)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quant_kernels_bit_identical_to_materialized_dequant(kv_dtype):
    """The exactness gate for fused dequant: each quantized Pallas kernel
    (interpret mode) must be BITWISE identical to its unquantized twin run
    on pre-dequantized pages. In-register dequant performs the exact same
    float32 multiply the oracle materializes, so fusing it may never
    change a single output bit."""
    from repro.kernels.paged_attention import (
        paged_context_attention_pallas, paged_context_attention_quant_pallas,
        paged_decode_attention_quant_pallas, paged_verify_attention_pallas,
        paged_verify_attention_quant_pallas)
    from repro.models import quant as Q

    b, hq, hkv, d = 2, 4, 2, 32
    bs, n_blocks = 16, 16
    k = rn(2, n_blocks, bs, hkv, d)
    v = rn(3, n_blocks, bs, hkv, d)
    kq, ks = Q.quantize_kv_rows(k, kv_dtype)
    vq, vs = Q.quantize_kv_rows(v, kv_dtype)
    kd, vd = Q.dequantize_kv(kq, ks), Q.dequantize_kv(vq, vs)

    bt = jnp.asarray(np.array([[3, 1, 4, 0, 0], [5, 9, 2, 6, 8]], np.int32))
    q = rn(1, b, 1, hq, d)
    kv_len = jnp.array([41, 80])             # ragged + full tables
    o_fused = paged_decode_attention_quant_pallas(
        q, kq, vq, ks, vs, bt, kv_len=kv_len, interpret=True)
    o_mat = paged_decode_attention_pallas(q, kd, vd, bt, kv_len=kv_len,
                                          interpret=True)
    assert np.array_equal(np.asarray(o_fused), np.asarray(o_mat))

    qc = rn(4, b, 8, hq, d)
    q_start = jnp.array([5, 0])
    c_len = jnp.array([13, 8])
    o_fused = paged_context_attention_quant_pallas(
        qc, kq, vq, ks, vs, bt, q_start=q_start, kv_len=c_len,
        interpret=True)
    o_mat = paged_context_attention_pallas(
        qc, kd, vd, bt, q_start=q_start, kv_len=c_len, interpret=True)
    assert np.array_equal(np.asarray(o_fused), np.asarray(o_mat))

    qv = rn(5, b, 4, hq, d)
    kv_start = jnp.array([41, 76])
    v_len = jnp.array([45, 78])              # ragged candidate counts
    o_fused = paged_verify_attention_quant_pallas(
        qv, kq, vq, ks, vs, bt, kv_start=kv_start, kv_len=v_len,
        interpret=True)
    o_mat = paged_verify_attention_pallas(
        qv, kd, vd, bt, kv_start=kv_start, kv_len=v_len, interpret=True)
    assert np.array_equal(np.asarray(o_fused), np.asarray(o_mat))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quant_kernels_vs_oracle_and_xla_dispatch(kv_dtype):
    """Quantized Pallas kernels against the pure-JAX dequant-whole-pool
    oracles at the repo's established kernel tolerance, and the ops XLA
    dispatch BITWISE against contiguous decode on dequantized gathered
    pages (mirroring test_paged_ops_xla_bit_identical_to_contiguous)."""
    from repro.kernels.paged_attention import (
        paged_decode_attention_quant_pallas)
    from repro.models import quant as Q

    b, hq, hkv, d = 2, 4, 2, 32
    bs, n_blocks = 16, 12
    k = rn(2, n_blocks, bs, hkv, d)
    v = rn(3, n_blocks, bs, hkv, d)
    kq, ks = Q.quantize_kv_rows(k, kv_dtype)
    vq, vs = Q.quantize_kv_rows(v, kv_dtype)
    bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6]], np.int32))
    q = rn(1, b, 1, hq, d)
    kv_len = jnp.array([19, 64])

    o_pal = paged_decode_attention_quant_pallas(
        q, kq, vq, ks, vs, bt, kv_len=kv_len, interpret=True)
    o_ref = ref.paged_decode_attention_quant_ref(
        q, kq, vq, ks, vs, bt, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=2e-5)

    o_ops = ops.paged_decode_attention(q, kq, vq, bt, kv_len=kv_len,
                                       k_scale=ks, v_scale=vs)
    o_contig = ops.decode_attention(
        q, ref.gather_pages(ref.dequant_pages(kq, ks), bt),
        ref.gather_pages(ref.dequant_pages(vq, vs), bt), kv_len=kv_len)
    assert np.array_equal(np.asarray(o_ops), np.asarray(o_contig))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quant_scatter_gather_roundtrip_error_bound(kv_dtype):
    """quantize -> scatter_rows_to_pages -> gather -> dequant round-trip:
    the landed pages must equal direct quantization of the rows (scatter
    adds no error), and the dequantized values must sit within the
    scheme's per-element bound of the originals."""
    from repro.models import quant as Q

    m, S, h, d, bs = 2, 16, 2, 32, 8
    n_blocks = 1 + m * (S // bs)
    rows = {"k": rn(11, m, S, h, d), "v": rn(12, m, S, h, d)}
    pool = {
        "k": jnp.zeros((n_blocks, bs, h, d), Q.kv_storage_dtype(kv_dtype)),
        "v": jnp.zeros((n_blocks, bs, h, d), Q.kv_storage_dtype(kv_dtype)),
        "k_scale": jnp.zeros((n_blocks, bs, h), jnp.float32),
        "v_scale": jnp.zeros((n_blocks, bs, h), jnp.float32),
    }
    dest = jnp.arange(1, n_blocks, dtype=jnp.int32)
    out = M.scatter_rows_to_pages(pool, rows, dest)
    for n in ("k", "v"):
        direct_q, direct_s = Q.quantize_kv_rows(rows[n], kv_dtype)
        landed_q = np.asarray(out[n][dest]).reshape(m, S, h, d)
        landed_s = np.asarray(out[n + "_scale"][dest]).reshape(m, S, h)
        np.testing.assert_array_equal(
            landed_q, np.asarray(direct_q, landed_q.dtype))
        np.testing.assert_array_equal(landed_s, np.asarray(direct_s))
        back = np.asarray(Q.dequantize_kv(out[n][dest], out[n + "_scale"][dest])
                          ).reshape(m, S, h, d)
        want = np.asarray(rows[n])
        if kv_dtype == "int8":
            # symmetric rounding: at most half a quantization step per
            # element, with the step set by each token-head's scale
            step = np.asarray(direct_s)[..., None]
            assert (np.abs(back - want) <= step * 0.51).all()
        else:
            # fp8 e4m3: half-ulp relative error (2^-4) in the normal
            # range plus the fixed subnormal step (2^-9 of the scale)
            # for elements that quantize below the min normal exponent
            step = np.asarray(direct_s)[..., None]
            assert (np.abs(back - want)
                    <= np.abs(want) * 0.0625 + step * 0.0021).all()


def test_quant_pool_init_guard_layers_and_legacy_width():
    """init_layer_paged_cache: kv_dtype=None keeps the legacy pool (no
    scale leaves, model dtype); "bf16" forces the storage width without
    scales; "int8" adds f32 scale pools; guard layers ignore kv_dtype."""
    cfg = get_config("granite-8b").reduced()
    legacy = M.init_layer_paged_cache(cfg, 1, 6, 8, 2)
    assert "k_scale" not in legacy
    assert legacy["k"].dtype == jnp.dtype(cfg.dtype)
    wide = M.init_layer_paged_cache(cfg, 1, 6, 8, 2, kv_dtype="bf16")
    assert "k_scale" not in wide and wide["k"].dtype == jnp.bfloat16
    quant = M.init_layer_paged_cache(cfg, 1, 6, 8, 2, kv_dtype="int8")
    assert quant["k"].dtype == jnp.int8
    assert quant["k_scale"].dtype == jnp.float32
    assert quant["k_scale"].shape == quant["k"].shape[:3]
    guarded = M.init_layer_paged_cache(cfg, 1, 6, 8, 2, kv_dtype="int8",
                                       kv_guard_layers=(1,))
    assert "k_scale" not in guarded
    assert guarded["k"].dtype == jnp.dtype(cfg.dtype)


def test_cow_after_quantize_copies_scales_with_payload():
    """COW safety on quantized pools: copy_cache_pages must duplicate the
    scale leaves alongside the payload — a payload copied without its
    scales dequantizes to garbage — and writing to the copy must leave
    the source page untouched (the refcount contract)."""
    cfg = get_config("granite-8b").reduced()
    cache = M.init_paged_cache(cfg, 6, 4, 2, kv_dtype="int8")
    poked = {}
    for lk, sub in cache.items():
        if "k_scale" not in sub:
            poked[lk] = sub
            continue
        poked[lk] = {
            "k": sub["k"].at[:, 2].set(7),
            "v": sub["v"].at[:, 2].set(-7),
            "k_scale": sub["k_scale"].at[:, 2].set(0.25),
            "v_scale": sub["v_scale"].at[:, 2].set(0.5),
        }
    out = M.copy_cache_pages(poked, [2], [4])
    checked = 0
    for lk, sub in out.items():
        if "k_scale" not in sub:
            continue
        checked += 1
        for n in ("k", "v", "k_scale", "v_scale"):
            np.testing.assert_array_equal(np.asarray(sub[n][:, 4]),
                                          np.asarray(poked[lk][n][:, 2]))
        # divergence after the copy: the source page keeps its contents
        div = sub["k_scale"].at[:, 4].set(9.0)
        assert (np.asarray(div[:, 2]) == 0.25).all()
    assert checked > 0


@pytest.mark.parametrize("kv_dtype", ["int8", "bf16"])
def test_paged_serving_int8_pool_matches_fp32_tokens(kv_dtype):
    """End-to-end: serving with a quantized (or narrowed) page pool must
    produce the same greedy tokens as the model-precision pool on a short
    workload — KV quantization error at these scales stays under the
    argmax margin on all but a near-tie logit pair, so at most one
    request may diverge (the statistical match RATE is measured by
    benchmarks/bench_quant_kv.py, not asserted here) — and report the
    byte savings in ServeStats."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    reqs_f = _mk_reqs(cfg)
    PagedPipelineBatcher(_pipe(cfg, params), n_slots=3, max_len=48,
                         block_size=8).serve(reqs_f, deadline=1e9)
    reqs_q = _mk_reqs(cfg)
    eng = PagedPipelineBatcher(_pipe(cfg, params), n_slots=3, max_len=48,
                               block_size=8, kv_dtype=kv_dtype)
    stats = eng.serve(reqs_q, deadline=1e9)
    assert stats.kv_bytes_resident > 0
    assert stats.kv_bytes_saved > 0
    assert f"kv=" in stats.summary()
    matched = sum(list(rf.output) == list(rq.output)
                  for rf, rq in zip(reqs_f, reqs_q))
    assert matched >= len(reqs_f) - 1, (matched, len(reqs_f))


def test_quant_serving_guard_layers_stay_model_precision():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    # the reduced config has 2 layers; guard the first only so the test
    # still sees one quantized pool alongside the pinned one
    eng = PagedPipelineBatcher(_pipe(cfg, params), n_slots=2, max_len=48,
                               block_size=8, kv_dtype="int8",
                               kv_guard_layers=(0,))
    reqs = _mk_reqs(cfg, n=2)
    eng.serve(reqs, deadline=1e9)
    dts = set()
    for st_caches in eng.pipeline.paged_caches:
        for c in st_caches:
            if isinstance(c, dict) and "k" in c:
                dts.add(np.asarray(c["k"]).dtype.name)
    # both the guarded (model-precision) and the quantized pools exist
    assert "int8" in dts and len(dts) == 2, dts


# ---------------------------------------------------------------------------
# Scheduler-side precision pricing
# ---------------------------------------------------------------------------

def test_cost_model_kv_dtype_pricing():
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    task = cm.Task(batch=1, s_in=128, s_out=64)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    c = cl.case_study_cluster()
    devs = [0, 1, 2, 3]
    base = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16)
    for name, payload in (("int8", 1.0), ("fp8", 1.0), ("bf16", 2.0)):
        capped = cm.concurrent_capacity(c, devs, 48, prof, task,
                                        block_size=16, kv_dtype=name)
        eff = cm.kv_dtype_bytes_per_el(name)
        want = task.bytes_per_el / eff
        assert capped >= base, (name, capped, base)
        # capacity scales (within rounding) by the width ratio
        assert abs(capped / base - want) / want < 0.1, (name, capped, base)
        mig0 = cm.kv_migration_bytes(prof, task, block_size=16)
        mig1 = cm.kv_migration_bytes(prof, task, block_size=16,
                                     kv_dtype=name)
        assert mig1 == pytest.approx(mig0 * eff / task.bytes_per_el)
    # int8 at a bf16 task: ~1.94x capacity, ~1.94x fewer migration bytes
    int8 = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16,
                                  kv_dtype="int8")
    assert int8 >= 1.8 * base


def test_choose_kv_dtypes_quantizes_only_memory_bound_replicas():
    from repro.core.genetic import choose_kv_dtypes
    from repro.core.plan import PipelinePlan, StagePlan

    plans = [PipelinePlan([StagePlan([0], 48)], cost=1.0, bottleneck=0.5),
             PipelinePlan([StagePlan([1], 48)], cost=1.0, bottleneck=0.5)]
    # replica 0 roomy, replica 1 memory-bound at default precision
    caps = {0: 100, 1: 1}

    def capacity_at(p, kvd):
        return caps[p.stages[0].device_ids[0]]
    out = choose_kv_dtypes(plans, capacity_at, rate=4.0)
    assert out == [None, "int8"]


def test_search_kv_dtype_lands_in_result():
    """kv_dtype_search=True: the genetic search reports a per-replica
    precision vector aligned with the winning assignment, quantizing the
    capacity-constrained replicas."""
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    from repro.core.scheduler import schedule
    task = cm.Task(batch=1, s_in=512, s_out=256)
    res = schedule(cl.case_study_cluster(), "llama2-70b", task,
                   deadline=10.0, rate=40.0, iters=6, seed=0,
                   paper_exact=True, kv_block_size=16,
                   kv_dtype_search=True)
    assert res.kv_dtypes is not None
    assert len(res.kv_dtypes) == len(res.assignment.pipelines)
    assert all(d in (None, "int8", "fp8") for d in res.kv_dtypes)
    # the demanding workload must push at least one replica to quantize
    assert any(d is not None for d in res.kv_dtypes), res.kv_dtypes
