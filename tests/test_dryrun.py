"""Dry-run machinery on a small in-subprocess mesh: every arch x shape must
lower and compile on a (2,2) (data, model) mesh of 4 host devices, and the
roofline extraction must produce sane terms. (The production 512-device
sweep is scripts/run_dryruns.py; this guards the machinery in CI.)"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

CODE = textwrap.dedent("""
    import os, json, sys
    import jax
    from repro.launch import specs, roofline
    from repro.configs.base import INPUT_SHAPES

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    out = {}
    for arch, shape in [("xlstm-125m", "decode_32k"),
                        ("whisper-base", "decode_32k"),
                        ("h2o-danube-1.8b", "long_500k"),
                        ("granite-moe-3b-a800m", "decode_32k")]:
        fn, structs, shs, jkw, cfg = specs.build_dryrun(arch, shape, mesh,
                                                        False)
        compiled = jax.jit(fn, in_shardings=shs, **jkw).lower(
            *structs).compile()
        rl = roofline.extract(
            compiled,
            model_flops=roofline.model_flops_estimate(
                cfg, INPUT_SHAPES[shape]),
            chips=4)
        out[f"{arch}/{shape}"] = {
            "flops": rl.flops, "bytes": rl.hbm_bytes,
            "dominant": rl.dominant,
            "uf": rl.useful_flops_frac,
        }
    print("JSON" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=560)
    assert p.returncode == 0, p.stderr[-3000:]
    payload = [l for l in p.stdout.splitlines() if l.startswith("JSON")][0]
    out = json.loads(payload[4:])
    for tag, rec in out.items():
        assert rec["flops"] > 0, tag
        assert rec["bytes"] > 0, tag
        assert 0 < rec["uf"] <= 2.0, (tag, rec["uf"])
