"""repro-lint + KVSAN (repro.analysis).

Three bars:

  * every lint rule demonstrably FIRES on the seeded-violation corpus
    (tests/fixtures/lint/), respects ``# repro: noqa[rule-id]``, and stays
    silent on the sanctioned idiom — and the real ``src/`` tree is clean;
  * every KVSAN violation class raises on a hand-driven BlockPool /
    HostPagePool, and legal lifecycle interleavings never do;
  * serving under ``kvsan=True`` is pure observation: mixed prefix / spec /
    preemption traffic produces token-identical outputs to sanitizer-off
    runs, with zero violations and zero leaks.
"""
import os

import jax
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.analysis import kvsan as K
from repro.analysis import registry as R
from repro.analysis.lint import (Finding, lint_file, lint_paths,
                                 lint_source, main as lint_main)
from repro.analysis.kvsan import KVSanitizer, KVSanViolation
from repro.serving.block_manager import (BlockPool, HostPagePool,
                                         NULL_BLOCK)

HERE = os.path.dirname(os.path.abspath(__file__))
CORPUS = os.path.join(HERE, "fixtures", "lint")
ROOT = os.path.dirname(HERE)


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# repro-lint: the seeded-violation corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,expect", [
    ("fx_clock.py", ["clock-discipline"] * 4),
    ("fx_clock_noqa.py", []),
    (os.path.join("serving", "loop.py"), []),       # the clock seam itself
    (os.path.join("serving", "fx_jit.py"), ["jit-retrace"] * 3),
    (os.path.join("serving", "fx_jit_setup.py"), []),
    ("fx_jit_elsewhere.py", []),                    # rule scoped to serving
    ("fx_kernel.py", ["kernel-oracle"]),
    ("fx_refcount.py", ["refcount-pairing"] * 2),
    ("fx_hygiene.py", ["bare-except"] + ["mutable-default"] * 2
     + ["unseeded-rng"] * 2),
    ("fx_span.py", ["span-pairing"] * 2),
    ("fx_span_noqa.py", []),
    ("fx_clean.py", []),
])
def test_corpus_fixture(fixture, expect):
    findings = lint_file(os.path.join(CORPUS, fixture))
    assert rules_of(findings) == sorted(expect), "\n".join(map(str, findings))


def test_findings_format_file_line_rule():
    f = lint_file(os.path.join(CORPUS, "fx_kernel.py"))[0]
    s = str(f)
    assert s.startswith(f"{f.path}:{f.line} kernel-oracle "), s
    assert "mystery_attention_pallas" in s


def test_noqa_suppresses_only_named_rule():
    src = "import time\n\ndef f():\n" \
          "    return time.time()  # repro: noqa[unseeded-rng]\n"
    assert rules_of(lint_source(src, "x.py")) == ["clock-discipline"]
    src2 = src.replace("noqa[unseeded-rng]", "noqa[clock-discipline]")
    assert lint_source(src2, "x.py") == []
    # bare noqa silences everything on the line
    src3 = src.replace("noqa[unseeded-rng]", "noqa")
    assert lint_source(src3, "x.py") == []


def test_parse_error_is_a_finding_not_a_crash():
    out = lint_source("def broken(:\n", "bad.py")
    assert len(out) == 1 and out[0].rule == "parse-error"


def test_serving_scope_by_stem():
    # "serving" in the file STEM also opts into the jit-retrace rule
    src = "import jax\n\ndef step(xs):\n    return jax.jit(len)(xs)\n"
    assert "jit-retrace" in rules_of(lint_source(src, "myserving_bench.py"))
    assert "jit-retrace" not in rules_of(lint_source(src, "bench.py"))


def test_src_tree_is_clean():
    # the CI gate, enforced from inside the suite too: the shipped tree
    # must lint clean (noqa pragmas are part of the tree)
    findings = lint_paths([os.path.join(ROOT, "src")])
    assert findings == [], "\n".join(map(str, findings))


def test_cli_exit_codes(capsys):
    assert lint_main(["--list-rules"]) == 0
    assert lint_main([os.path.join(CORPUS, "fx_clean.py")]) == 0
    rc = lint_main([os.path.join(CORPUS, "fx_hygiene.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "bare-except" in out and "fx_hygiene.py" in out


# ---------------------------------------------------------------------------
# kernel/oracle registry
# ---------------------------------------------------------------------------

def test_registry_sound_on_real_tree():
    assert R.check_registry() == []
    kernels = R.pallas_kernels()
    # the scan sees every registered kernel, and vice versa
    assert set(kernels) == set(R.KERNEL_ORACLES)
    assert len(kernels) >= 9


def test_registry_flags_synthetic_breakage(tmp_path):
    mod = tmp_path / "src" / "repro" / "kernels" / "paged_attention.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def rogue_pallas(q):\n    return q\n")
    problems = "\n".join(R.check_registry(root=str(tmp_path)))
    assert "rogue_pallas" in problems            # unregistered kernel
    assert "matches no *_pallas definition" in problems   # stale entries
    assert "not found in src/repro/kernels/ref.py" in problems
    # the unregistered kernel also fires the lint rule on the file itself
    assert rules_of(lint_file(str(mod))) == ["kernel-oracle"]


# ---------------------------------------------------------------------------
# KVSAN: hand-driven violation classes
# ---------------------------------------------------------------------------

def _sanitized_pool(n=8, bs=4, **kw):
    san = KVSanitizer(**kw)
    pool = BlockPool(n, bs)
    san.attach_pool(0, pool)
    return san, pool


def test_kvsan_double_free():
    san, pool = _sanitized_pool()
    (b,) = pool.alloc(1)
    pool.free(b)
    with pytest.raises(KVSanViolation, match="double free"):
        pool.free(b)
    assert san.violations


def test_kvsan_incref_dead_block():
    san, pool = _sanitized_pool()
    (b,) = pool.alloc(1)
    pool.free(b)
    with pytest.raises(KVSanViolation, match="use-after-free alias"):
        pool.incref(b)


def test_kvsan_write_after_free():
    san, pool = _sanitized_pool()
    (b,) = pool.alloc(1)
    pool.free(b)
    with pytest.raises(KVSanViolation, match="use-after-free write"):
        san.note_write(0, [b])


def test_kvsan_kernel_reads_freed_block():
    san, pool = _sanitized_pool(bs=4)
    blocks = pool.alloc(2)
    san.note_write(0, blocks)
    pool.free(blocks[1])
    with pytest.raises(KVSanViolation, match="use-after-free"):
        san.slot_access(0, blocks, kv_len=7, write_start=7, block_size=4)


def test_kvsan_read_before_write():
    san, pool = _sanitized_pool(bs=4)
    blocks = pool.alloc(2)           # allocated, nothing ever written
    with pytest.raises(KVSanViolation, match="no write ever landed"):
        san.slot_access(0, blocks, kv_len=7, write_start=6, block_size=4)


def test_kvsan_reads_unwritten_tokens():
    san, pool = _sanitized_pool(bs=4)
    blocks = pool.alloc(1)
    # decode at position 2 attends over tokens [0, 2) of an ALLOC block
    with pytest.raises(KVSanViolation, match="unwritten tokens"):
        san.slot_access(0, blocks, kv_len=3, write_start=2, block_size=4)


def test_kvsan_legal_lifecycle_is_silent():
    san, pool = _sanitized_pool(bs=4)
    blocks = pool.alloc(2)
    # prefill writes [0, 6); decode extends one token at a time
    san.slot_access(0, blocks, kv_len=6, write_start=0, block_size=4)
    for pos in range(6, 8):
        san.slot_access(0, blocks, kv_len=pos + 1, write_start=pos,
                        block_size=4)
    # pure read (extraction) of the written range
    san.slot_access(0, blocks, kv_len=8, write_start=8, block_size=4)
    san.on_spill(0, blocks[0])
    pool.incref(blocks[0])
    pool.free(blocks[0])
    for b in blocks:
        pool.free(b)
    assert san.violations == [] and san.leaks == 0
    assert san.state(0, blocks[0]) == K.FREE


def test_kvsan_table_too_short_and_null_inside():
    san, pool = _sanitized_pool(bs=4)
    blocks = pool.alloc(1)
    san.note_write(0, blocks)
    with pytest.raises(KVSanViolation, match="needs"):
        san.slot_access(0, blocks, kv_len=9, write_start=9, block_size=4)
    with pytest.raises(KVSanViolation, match="null block inside"):
        san.slot_access(0, [blocks[0], NULL_BLOCK], kv_len=6,
                        write_start=6, block_size=4)


def test_kvsan_cow_source_must_be_written():
    san, pool = _sanitized_pool(bs=4)
    src_b, dst_b = pool.alloc(2)
    with pytest.raises(KVSanViolation, match="COW copies from"):
        san.on_copy(0, src_b, dst_b)
    san.note_write(0, [src_b])
    san.on_copy(0, src_b, dst_b)             # now legal; dst becomes WRITTEN
    assert san.state(0, dst_b) == K.WRITTEN
    pool.free(dst_b)
    with pytest.raises(KVSanViolation, match="COW copies into freed"):
        san.on_copy(0, src_b, dst_b)


def test_kvsan_spill_of_unwritten_block():
    san, pool = _sanitized_pool(bs=4)
    (b,) = pool.alloc(1)
    with pytest.raises(KVSanViolation, match="spill extracts"):
        san.on_spill(0, b)


def test_kvsan_leak_counted_once_then_clears():
    san, pool = _sanitized_pool(bs=4)
    (b,) = pool.alloc(1)
    san.note_write(0, [b])
    # no table or index explains the reference -> one leak, counted once
    assert san.audit_pool(0, pool, {}) == 1
    assert san.audit_pool(0, pool, {}) == 0      # already counted
    assert san.leaks == 1 and any("leak" in v for v in san.violations)
    assert san.audit_pool(0, pool, {b: 1}) == 0  # now explained
    pool.free(b)
    assert san.audit_pool(0, pool, {}) == 0
    assert san.leaks == 1                        # monotonic, no re-count


def test_kvsan_dangling_reference_raises():
    san, pool = _sanitized_pool(bs=4)
    (b,) = pool.alloc(1)
    pool.free(b)
    with pytest.raises(KVSanViolation, match="dangling"):
        san.audit_pool(0, pool, {b: 1})


def test_kvsan_host_two_tier_alias():
    san = KVSanitizer()
    host = HostPagePool(4, block_size=4)
    san.attach_host(0, host)
    host.put(101, "payload")
    with pytest.raises(KVSanViolation, match="two-tier alias"):
        host.put(101, "payload-again")
    assert host.get(101) == "payload"            # promotion pops the shadow
    host.put(101, "payload")                     # re-demotion is legal
    san.audit_host(0, host)


def test_kvsan_host_shadow_divergence():
    san = KVSanitizer()
    host = HostPagePool(4, block_size=4)
    san.attach_host(0, host)
    host._pages[55] = "smuggled"                 # bypasses the wrapper
    with pytest.raises(KVSanViolation, match="host tier diverged"):
        san.audit_host(0, host)


def test_kvsan_host_lru_evict_keeps_shadow_in_sync():
    san = KVSanitizer()
    host = HostPagePool(2, block_size=4)
    dropped = []
    host.on_evict = dropped.append
    san.attach_host(0, host)                     # wraps AFTER wiring
    for h in (1, 2, 3):
        host.put(h, f"p{h}")
    assert dropped == [1]                        # original callback chained
    san.audit_host(0, host)                      # shadow followed the drop
    host.discard(2)
    san.audit_host(0, host)


def test_kvsan_quant_scale_payload_disagreement():
    san = KVSanitizer(quant=True)
    host = HostPagePool(4, block_size=4)
    san.attach_host(0, host)
    bare = [{"k": np.zeros(1), "v": np.zeros(1)}]
    with pytest.raises(KVSanViolation, match="without scale leaves"):
        host.put(7, bare)
    scaled = [{"k": np.zeros(1), "v": np.zeros(1),
               "k_scale": np.ones(1), "v_scale": np.ones(1)}]
    host.put(8, scaled)                          # coherent quant payload

    san_f = KVSanitizer(quant=False)
    host_f = HostPagePool(4, block_size=4)
    san_f.attach_host(0, host_f)
    host_f.put(7, bare)                          # coherent fp payload
    with pytest.raises(KVSanViolation, match="with scale leaves"):
        host_f.put(8, scaled)


def test_kvsan_shadow_refcount_divergence_raises():
    san, pool = _sanitized_pool(bs=4)
    (b,) = pool.alloc(1)
    pool._ref[b] = 3                             # corrupt behind the wrapper
    with pytest.raises(KVSanViolation, match="diverged"):
        san.audit_pool(0, pool, {b: 3})


# ---------------------------------------------------------------------------
# KVSAN: randomized legal-lifecycle property (hypothesis + seeded fallback)
# ---------------------------------------------------------------------------

def _drive_legal_lifecycle(seed: int) -> None:
    """Random but LEGAL alloc/write/decode/incref/free interleavings must
    keep the sanitizer silent, and the audit leak-free once every
    reference is explained."""
    rng = np.random.default_rng(seed)
    san, pool = _sanitized_pool(n=12, bs=4)
    live = {}                                     # bid -> extra refs
    written = set()
    for _ in range(200):
        op = rng.integers(0, 5)
        if op == 0 and pool.n_free > 0:
            (b,) = pool.alloc(1)
            live[b] = 0
        elif op == 1 and live:
            b = int(rng.choice(list(live)))
            san.note_write(0, [b])
            written.add(b)
        elif op == 2 and live:
            b = int(rng.choice(list(live)))
            pool.incref(b)
            live[b] += 1
        elif op == 3 and live:
            b = int(rng.choice(list(live)))
            pool.free(b)
            if live[b] > 0:
                live[b] -= 1
            else:
                del live[b]
                written.discard(b)
        elif op == 4:
            ws = [b for b in written if b in live]
            if ws:
                san.slot_access(0, [ws[0]], kv_len=4, write_start=4,
                                block_size=4)
    expected = {b: n + 1 for b, n in live.items()}
    assert san.audit_pool(0, pool, expected) == 0
    assert san.violations == [] and san.leaks == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_kvsan_legal_lifecycle_property(seed):
    _drive_legal_lifecycle(seed)


@pytest.mark.parametrize("seed", [0, 1, 2023])
def test_kvsan_legal_lifecycle_seeded(seed):
    # seeded fallback: runs even where hypothesis is absent
    _drive_legal_lifecycle(seed)


# ---------------------------------------------------------------------------
# KVSAN under real serving: token identity + zero reports
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pipe_factory():
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.pipeline import AsymmetricPipeline

    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])
    return cfg, pipe


def _mixed_workload(cfg, seed: int):
    """Prefix riders + unique prompts + enough decode growth to preempt."""
    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
    reqs = []
    for i in range(7):
        if i % 2 == 0:
            tail = rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 8))).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=int(rng.integers(8, 16))
                                  ).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(8, 13)),
                            arrival=0.1 * i))
    return reqs


def _serve_mixed(pipe, cfg, seed, *, kvsan):
    from repro.serving.continuous import PagedPipelineBatcher
    from repro.serving.spec import SpecConfig

    # admit on bare prompt footprint (admit_headroom=0) over a pool too
    # small for every admitted generation: decode growth must run the
    # pool dry and preempt, on top of prefix sharing and spec chunks
    b = PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             stage_blocks=[9, 9], admit_headroom=0,
                             prefix_caching=True, spec=SpecConfig(k=2),
                             kvsan=kvsan)
    reqs = _mixed_workload(cfg, seed)
    stats = b.serve(reqs, deadline=1e9)
    return b, reqs, stats


@pytest.mark.parametrize("seed", [3, 11])
def test_kvsan_serving_token_identical_and_silent(pipe_factory, seed):
    cfg, pipe = pipe_factory
    _, reqs_off, stats_off = _serve_mixed(pipe, cfg, seed, kvsan=False)
    b, reqs_on, stats_on = _serve_mixed(pipe, cfg, seed, kvsan=True)
    # the traffic genuinely mixes prefix hits, spec steps and preemption
    assert stats_off.prefix_hits > 0 and stats_off.spec_steps > 0, \
        stats_off.summary()
    assert stats_off.preemptions > 0, stats_off.summary()
    # pure observation: identical outputs, identical counters, no reports
    for ro, rn_ in zip(reqs_off, reqs_on):
        assert list(ro.output) == list(rn_.output), ro.rid
    assert stats_on.preemptions == stats_off.preemptions
    assert stats_on.kvsan_leaks == 0 and stats_off.kvsan_leaks == 0
    assert b._san is not None and b._san.violations == []


def test_kvsan_detects_injected_leak(pipe_factory):
    cfg, pipe = pipe_factory
    b, _, stats = _serve_mixed(pipe, cfg, 3, kvsan=True)
    assert stats.kvsan_leaks == 0
    si = next(i for i, p in enumerate(b._pools) if p is not None)
    pool = b._pools[si]
    # inject the bug KVSAN exists for: a reference no table/index explains
    (bid,) = pool.alloc(1)
    b._san.note_write(si, [bid])
    b._kvsan_audit()
    assert b.kvsan_leaks == 1
    assert any("leak" in v for v in b._san.violations)
    pool.free(bid)                    # fixed: audit stays at one count
    b._kvsan_audit()
    assert b.kvsan_leaks == 1


def test_kvsan_counter_reaches_serve_stats(pipe_factory):
    from repro.serving.loop import run_serve_loop, VirtualClock

    cfg, pipe = pipe_factory
    b, _, _ = _serve_mixed(pipe, cfg, 3, kvsan=True)
    si = next(i for i, p in enumerate(b._pools) if p is not None)
    (bid,) = b._pools[si].alloc(1)
    b._san.note_write(si, [bid])
    # the leak is discovered by the per-iteration audit DURING the next
    # serve, so it lands inside the loop's delta window
    stats = run_serve_loop([b], _mixed_workload(cfg, 5), deadline=1e9,
                           clock=VirtualClock())
    assert stats.kvsan_leaks == 1     # delta-reported like every counter
    assert "KVSAN-LEAKS=1" in stats.summary()
