"""Config registry + published-size sanity."""
import pytest

from repro.configs import get_config, list_archs

ASSIGNED = ["granite-8b", "jamba-v0.1-52b", "h2o-danube-1.8b",
            "granite-moe-3b-a800m", "granite-20b", "xlstm-125m",
            "paligemma-3b", "codeqwen1.5-7b", "phi3.5-moe-42b-a6.6b",
            "whisper-base"]

# (total params, active params) bounds in billions, from the cited sources
PUBLISHED = {
    "granite-8b": (7.0, 9.5),
    "jamba-v0.1-52b": (48.0, 55.0),
    "h2o-danube-1.8b": (1.5, 2.1),
    "granite-20b": (18.0, 22.0),
    "llama2-70b": (65.0, 72.0),
    "phi3.5-moe-42b-a6.6b": (39.0, 45.0),
    "whisper-base": (0.05, 0.09),
    "xlstm-125m": (0.1, 0.2),
}


def test_all_assigned_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs


@pytest.mark.parametrize("arch", ASSIGNED + ["llama2-70b"])
def test_param_counts(arch):
    cfg = get_config(arch)
    total = cfg.total_params / 1e9
    if arch in PUBLISHED:
        lo, hi = PUBLISHED[arch]
        assert lo <= total <= hi, (arch, total)
    assert cfg.active_params <= cfg.total_params


def test_active_params_moe():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert 5.5 <= phi.active_params / 1e9 <= 7.5      # ~6.6B active
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.active_params < 0.35 * jamba.total_params


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_contract(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 4
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.num_layers % (len(r.layer_pattern) if r.layer_pattern else 1) == 0


def test_layer_kinds_jamba():
    cfg = get_config("jamba-v0.1-52b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    assert kinds.count("attn") == 4                  # 1:7 over 32 layers
    assert kinds.count("mamba") == 28
    moe_layers = [i for i in range(cfg.num_layers) if cfg.is_moe_layer(i)]
    assert len(moe_layers) == 16                     # every other layer
