"""Asymmetric pipeline executor + engine: equivalence with the monolithic
model, multi-device TP via a subprocess with 4 virtual host devices, and an
end-to-end served workload."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import Assignment, PipelinePlan, StagePlan
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import synth_workload

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-v0.1-52b",
                                  "whisper-base"])
def test_pipeline_matches_monolithic(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    b, s = 2, 12
    toks = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (b, s)).astype(np.int32)
    extras = {}
    if cfg.is_encoder_decoder:
        extras["enc_frames"] = 0.1 * jax.random.normal(
            KEY, (b, cfg.encoder_seq_len, cfg.d_model))

    cache = M.init_cache(cfg, b, s + 4)
    lg_ref, cache2 = M.prefill(cfg, params, {"tokens": jnp.asarray(toks),
                                             **extras}, cache)
    nxt = np.asarray(jnp.argmax(lg_ref, -1))
    lg2_ref, _ = M.decode_step(cfg, params, jnp.asarray(nxt), cache2, s)

    dev = jax.devices()[0]
    L = cfg.num_layers
    split = [max(1, L // 3), L - max(1, L // 3)]
    pipe = AsymmetricPipeline(cfg, params, split, [[dev], [dev]])
    lg = pipe.prefill(toks, max_new=4, batch_extras=extras)
    np.testing.assert_allclose(lg, np.asarray(lg_ref), atol=2e-4)
    lg2 = pipe.decode_step(nxt)
    np.testing.assert_allclose(lg2, np.asarray(lg2_ref), atol=2e-3)


def test_generate_shapes():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    pipe = AsymmetricPipeline(cfg, params, [cfg.num_layers], [[dev]])
    toks = np.random.RandomState(1).randint(0, cfg.vocab_size,
                                            (3, 8)).astype(np.int32)
    out = pipe.generate(toks, max_new=5)
    assert out.shape == (3, 5)
    assert out.dtype == np.int32


def test_engine_serves_workload():
    cfg = get_config("xlstm-125m").reduced()
    asg = Assignment([PipelinePlan([StagePlan([0], cfg.num_layers)],
                                   cost=0.1, bottleneck=0.1)])
    eng = InferenceEngine(cfg, asg, key=KEY)
    reqs = synth_workload(rate=30.0, duration=0.3, vocab=cfg.vocab_size,
                          prompt_len=8, prompt_jitter=3, out_len=3, seed=2)
    stats = eng.serve(reqs, deadline=60.0)
    assert len(stats.latencies) == len(reqs)
    assert stats.attainment == 1.0
    for r in reqs:
        assert r.output is not None and len(r.output) == 3


@pytest.mark.slow
def test_asymmetric_tp_multidevice_subprocess():
    """TP=2 stage + TP=2 stage and TP=4 + TP=1 across 4 virtual devices
    reproduce the single-device logits exactly."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import model as M
        from repro.serving.pipeline import AsymmetricPipeline
        key = jax.random.PRNGKey(0)
        devs = jax.devices()
        assert len(devs) == 4
        for arch in ("granite-8b", "phi3.5-moe-42b-a6.6b"):
            cfg = get_config(arch).reduced()
            params = M.init_params(cfg, key)
            toks = np.random.RandomState(0).randint(
                0, cfg.vocab_size, (2, 12)).astype(np.int32)
            cache = M.init_cache(cfg, 2, 16)
            lg_ref, cache2 = M.prefill(cfg, params,
                                       {"tokens": jnp.asarray(toks)}, cache)
            nxt = np.asarray(jnp.argmax(lg_ref, -1))
            lg2_ref, _ = M.decode_step(cfg, params, jnp.asarray(nxt),
                                       cache2, 12)
            L = cfg.num_layers
            for sd in ([[devs[0], devs[1]], [devs[2], devs[3]]],
                       [[devs[0], devs[1], devs[2], devs[3]], [devs[0]]]):
                pipe = AsymmetricPipeline(cfg, params, [1, L - 1], sd)
                lg = pipe.prefill(toks, max_new=4)
                assert np.abs(lg - np.asarray(lg_ref)).max() < 2e-4, arch
                lg2 = pipe.decode_step(nxt)
                assert np.abs(lg2 - np.asarray(lg2_ref)).max() < 2e-3, arch
        print("OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=560)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "OK" in p.stdout
