"""Continuous (iteration-level) batching — beyond-paper extension.
Correctness bar: a request's tokens are identical to isolated generation
regardless of what shares the batch, including slot reuse under queueing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.continuous import ContinuousBatcher
from repro.serving.request import Request

KEY = jax.random.PRNGKey(0)


def _isolated(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, 48)
    lg, cache = M.prefill(cfg, params,
                          {"tokens": jnp.asarray(prompt)[None]}, cache)
    outs = []
    pos = len(prompt)
    for _ in range(n):
        nxt = int(np.asarray(lg).argmax())
        outs.append(nxt)
        lg, cache = M.decode_step(cfg, params, jnp.asarray([nxt]), cache, pos)
        pos += 1
    return outs


@pytest.mark.parametrize("arch", ["granite-8b", "phi3.5-moe-42b-a6.6b",
                                  "xlstm-125m"])
def test_continuous_equals_isolated(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=6 + 3 * i).astype(np.int32),
                    max_new_tokens=5) for i in range(3)]
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=48)
    cb.serve(reqs, deadline=1e9)
    for r in reqs:
        assert list(r.output) == _isolated(cfg, params, r.prompt, 5), r.rid


def test_swa_rejected():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = M.init_params(cfg, KEY)
    with pytest.raises(AssertionError):
        ContinuousBatcher(cfg, params)


def test_slot_lifecycle():
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    assert cb.free_slots() == [0, 1]
    r = Request(rid=7, prompt=np.arange(4, dtype=np.int32), max_new_tokens=2)
    cb.insert(r)
    assert cb.free_slots() == [1]
    done = {}
    while not done:
        done = cb.step()
    assert 7 in done and len(done[7]) == 2
    assert cb.free_slots() == [0, 1]
