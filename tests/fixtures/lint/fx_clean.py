"""Every sanctioned idiom together: must lint clean with zero findings."""
from typing import List, Optional

import numpy as np


class Table:
    def __init__(self, pool):
        self.pool = pool
        self.blocks: List[int] = []

    def grow(self) -> int:
        bid = self.pool.alloc()
        self.blocks.append(bid)
        return bid

    def release(self) -> None:
        for b in self.blocks:
            self.pool.free(b)
        self.blocks = []


def pick(xs: Optional[List[int]] = None, *, seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    xs = xs if xs is not None else [0]
    try:
        return xs[int(rng.integers(0, len(xs)))]
    except IndexError:
        return 0


def traced_iteration(tracer, work) -> None:
    # span-pairing sanctioned idioms: the context manager, and an
    # explicit begin/end pair closed in the SAME function
    with tracer.span("iteration"):
        work()
    sp = tracer.begin("serve")
    work()
    tracer.end(sp)
