"""Seeded violation: pool references acquired with no release path."""


class LeakyTable:
    def __init__(self, pool):
        self.pool = pool
        self.blocks = []

    def grow(self):
        self.blocks.append(self.pool.alloc())    # FIRES refcount-pairing

    def adopt(self, bid):
        self.pool.incref(bid)                    # FIRES refcount-pairing
        self.blocks.append(bid)


class PairedTable:
    def __init__(self, pool):
        self.pool = pool
        self.blocks = []

    def grow(self):
        self.blocks.append(self.pool.alloc())    # clean: release below

    def release(self):
        for b in self.blocks:
            self.pool.free(b)
        self.blocks = []
