"""The same jit/shape patterns OUTSIDE a serving path: the jit-retrace
rule is scoped to serving files, so this file is clean (an offline
benchmark re-jitting per call is wasteful, not a correctness hazard)."""
import jax
import jax.numpy as jnp


def bench_once(xs):
    fn = jax.jit(lambda x: x + 1)       # clean: not a serving path
    return fn(jnp.zeros(len(xs)))       # clean: not a serving path
