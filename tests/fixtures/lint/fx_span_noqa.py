"""The same unclosed begin(), noqa-suppressed: must lint clean.

A span that deliberately outlives the opening frame (a root span handed
back to the caller to close) is the only legitimate reason to suppress
span-pairing — and it must say so in an adjacent comment.
"""


def serve_root(tracer, run):
    # the root span deliberately outlives this helper; the caller closes
    # it after draining
    span = tracer.begin("serve")  # repro: noqa[span-pairing]
    run()
    return span
