"""A file AT serving/loop.py owns the Wall/Virtual clock seam: wall-clock
reads here are the sanctioned implementation, not a violation."""
import time


class WallClockFixture:
    def now(self) -> float:
        return time.monotonic()     # exempt: this file IS the clock

    def sleep_until(self, t: float) -> None:
        time.sleep(t)               # exempt
