"""Seeded violations: retrace hazards inside a serving-path file."""
import jax
import jax.numpy as jnp


class Batcher:
    def run_iteration(self, xs):
        step = jax.jit(lambda x: x + 1)       # FIRES jit-retrace
        pad = jnp.zeros(len(xs))              # FIRES jit-retrace
        return step(pad)

    def decode(self, xs):
        return jnp.ones((4, len(xs)))         # FIRES jit-retrace
