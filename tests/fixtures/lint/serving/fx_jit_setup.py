"""Sanctioned pattern: compile once in setup, reuse per iteration."""
import jax
import jax.numpy as jnp


class Batcher:
    def __init__(self, n_slots: int):
        self._step = jax.jit(lambda x: x + 1)    # sanctioned: setup
        self._pad = jnp.zeros((n_slots,))        # fixed bucket shape

    def build(self):
        self._decode = jax.jit(lambda x: x * 2)  # sanctioned: setup

    def run_iteration(self, xs):
        return self._step(self._pad)             # no construction here
