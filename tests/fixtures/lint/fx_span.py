"""Seeded span-pairing violations: tracer.begin() never closed.

An unclosed span leaves ``openSpans`` nonzero in the Chrome-trace export,
which ``validate_chrome_trace`` rejects — the linter catches it at review
time instead.
"""


def leaky_serve(tracer, work):
    span = tracer.begin("serve")        # fires: no end() in this function
    work()
    return span


class Engine:
    def __init__(self, tracer):
        self.tracer = tracer

    def leaky_iteration(self, work):
        sp = self.tracer.begin("iteration")   # fires: end() is elsewhere
        work()
        return sp

    def close(self, sp):
        # an end() in a DIFFERENT function does not pair the begin above:
        # the rule is per-function, matching the repo's discipline that a
        # span opens and closes in one frame (or uses the context manager)
        self.tracer.end(sp)
