"""Seeded violations: general hygiene rules."""
import random

import numpy as np


def risky(xs=[], opts={}):                  # FIRES mutable-default (x2)
    try:
        return xs[0]
    except:                                 # FIRES bare-except
        return None


def jitter():
    a = random.random()                     # FIRES unseeded-rng
    b = np.random.randint(0, 10)            # FIRES unseeded-rng
    return a + b


def seeded_ok(seed: int):
    rng = np.random.default_rng(seed)       # clean: explicit seed
    legacy = np.random.RandomState(seed)    # clean: explicit seed
    return rng.integers(0, 10) + legacy.randint(0, 10)
