"""Seeded violation: a Pallas kernel with no registered oracle."""


def mystery_attention_pallas(q, k, v):      # FIRES kernel-oracle
    return q


def _helper_pallas_launcher(q):             # clean: not *_pallas
    return q


class Wrapper:
    def bound_pallas(self):                 # clean: method, not top-level
        return None
