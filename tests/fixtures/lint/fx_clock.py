"""Seeded violations: wall-clock reads outside serving/loop.py."""
import datetime
import time


def stamp_iteration():
    t0 = time.time()            # FIRES clock-discipline
    time.sleep(0.01)            # FIRES clock-discipline
    wall = datetime.datetime.now()   # FIRES clock-discipline
    return t0, wall


def profile():
    return time.monotonic()     # FIRES clock-discipline
