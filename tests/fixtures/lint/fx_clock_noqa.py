"""The same wall-clock reads, intentionally suppressed."""
import time


def profile_offline_search():
    # offline profiling, not serving-path time (justification goes here)
    t0 = time.monotonic()       # repro: noqa[clock-discipline]
    t1 = time.time()            # repro: noqa
    return t1 - t0
