"""DeploymentPlan / ReplicaSpec: the typed plan surface replacing
SearchResult's parallel lists. Covers dimension None-ness semantics,
diff/apply round-trips (property-tested where hypothesis is available),
the deprecated SearchResult property shim, and the ServingConfig
argv/json round-trips."""
import argparse
import dataclasses
import warnings

import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.core.genetic import SearchResult
from repro.core.plan import (Assignment, DeploymentPlan, PipelinePlan,
                             ReplicaSpec, StagePlan)
from repro.serving.config import ServingConfig


def _pipe(devs, layers=4):
    return PipelinePlan([StagePlan(list(devs), layers)],
                        cost=0.1, bottleneck=0.1)


def _asg(*groups):
    return Assignment([_pipe(g) for g in groups])


# ---------------------------------------------------------------------------
# Dimension semantics
# ---------------------------------------------------------------------------

def test_from_search_preserves_noneness():
    asg = _asg([0, 1], [2, 3])
    plan = DeploymentPlan.from_search(asg)
    assert plan.num_replicas == 2
    # un-searched dimensions stay None, exactly like the old Optional
    # parallel lists
    assert plan.roles is None and plan.spec_ks is None
    assert plan.kv_dtypes is None and plan.host_blocks is None

    plan2 = DeploymentPlan.from_search(asg, roles=["prefill", "decode"],
                                       spec_ks=[2, 0])
    assert plan2.roles == ["prefill", "decode"]
    assert plan2.spec_ks == [2, 0]
    assert plan2.kv_dtypes is None          # still not searched
    assert plan2.dims == frozenset({"roles", "spec"})


def test_replica_key_is_device_set():
    r = ReplicaSpec(pipeline=_pipe([3, 1]))
    assert r.key == frozenset({1, 3})
    assert r.device_ids == [3, 1]


def test_assignment_round_trip():
    asg = _asg([0, 1], [2], [3, 4, 5])
    plan = DeploymentPlan.from_search(asg)
    got = plan.assignment
    assert [p.device_ids for p in got.pipelines] == \
        [p.device_ids for p in asg.pipelines]


# ---------------------------------------------------------------------------
# diff / apply
# ---------------------------------------------------------------------------

def _mk_plan(groups, roles=None):
    return DeploymentPlan.from_search(_asg(*groups), roles=roles)


def test_diff_empty_on_identical():
    a = _mk_plan([[0, 1], [2, 3]])
    d = a.diff(_mk_plan([[0, 1], [2, 3]]))
    assert d.is_empty


def test_diff_detects_add_remove_change():
    a = _mk_plan([[0, 1], [2, 3]], roles=["both", "both"])
    b = _mk_plan([[0, 1], [4, 5]], roles=["prefill", "decode"])
    d = a.diff(b)
    assert {tuple(sorted(r.key)) for r in d.removed} == {(2, 3)}
    assert {tuple(sorted(r.key)) for r in d.added} == {(4, 5)}
    # replica {0,1} survives but its role changed
    assert len(d.changed) == 1
    old, new = d.changed[0]
    assert old.key == new.key == frozenset({0, 1})
    assert old.role == "both" and new.role == "prefill"


def test_apply_round_trip_deterministic():
    rng = np.random.RandomState(7)
    for _ in range(50):
        n_dev = rng.randint(4, 12)
        devs = list(range(n_dev))
        rng.shuffle(devs)

        def cut(ds):
            groups, i = [], 0
            while i < len(ds):
                k = rng.randint(1, 4)
                groups.append(ds[i:i + k])
                i += k
            return groups

        ga = cut(devs)[:rng.randint(1, 5)]
        gb = cut(devs)[:rng.randint(1, 5)]
        roles_a = [rng.choice(["both", "prefill", "decode"]) for _ in ga]
        roles_b = [rng.choice(["both", "prefill", "decode"]) for _ in gb]
        a = _mk_plan(ga, roles=roles_a)
        b = _mk_plan(gb, roles=roles_b)
        assert a.apply(a.diff(b)).canonical() == b.canonical()
        assert b.apply(b.diff(a)).canonical() == a.canonical()


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_apply_round_trip_property(data):
    def plan(tag):
        n = data.draw(st.integers(1, 4), label=f"{tag}_replicas")
        groups, base = [], 0
        for i in range(n):
            k = data.draw(st.integers(1, 3), label=f"{tag}_width{i}")
            groups.append(list(range(base, base + k)))
            base += k
        roles = [data.draw(st.sampled_from(["both", "prefill", "decode"]),
                           label=f"{tag}_role{i}") for i in range(n)]
        return _mk_plan(groups, roles=roles)

    a, b = plan("a"), plan("b")
    assert a.apply(a.diff(b)).canonical() == b.canonical()


def test_diff_describe_mentions_changes():
    a = _mk_plan([[0, 1]], roles=["both"])
    b = _mk_plan([[0, 1], [2]], roles=["prefill", "decode"])
    txt = a.diff(b).describe()
    assert "+[" in txt and "->" in txt


# ---------------------------------------------------------------------------
# SearchResult deprecation shim
# ---------------------------------------------------------------------------

def _result(**dims):
    plan = DeploymentPlan.from_search(_asg([0, 1], [2, 3]), **dims)
    return SearchResult(plan=plan, attainment=1.0, history=[], evaluations=0)


def test_search_result_plan_is_primary():
    res = _result(roles=["prefill", "decode"])
    assert res.plan.roles == ["prefill", "decode"]
    # .assignment is NOT deprecated (it's the serving surface)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert res.assignment.num_replicas == 2


@pytest.mark.parametrize("name,value", [
    ("roles", ["prefill", "decode"]),
    ("spec_ks", [3, 0]),
    ("kv_dtypes", ["int8", None]),
    ("host_blocks", [4, 0]),
])
def test_search_result_deprecated_properties(name, value):
    res = _result(**{name: value})
    with pytest.warns(DeprecationWarning, match=name):
        assert getattr(res, name) == value
    # None-ness preserved for un-searched dimensions
    bare = _result()
    with pytest.warns(DeprecationWarning):
        assert getattr(bare, name) is None


# ---------------------------------------------------------------------------
# ServingConfig round-trips
# ---------------------------------------------------------------------------

def test_serving_config_argv_round_trip():
    cfg = ServingConfig(arch="granite-8b", reduced=True, rate=7.5,
                        cache_layout="paged", prefix_caching=True,
                        kvsan=True, kv_dtype="search", spec_decode=True,
                        spec_k=3, route_seed=11, host_mem_gb=2.0,
                        shared_prefix=16, disaggregate=True)
    assert ServingConfig.parse(cfg.to_args()) == cfg
    assert ServingConfig.parse([]) == ServingConfig()


def test_serving_config_json_round_trip():
    cfg = ServingConfig(arch="llama2-70b", block_size=32, kv_dtype="fp8",
                        cache_layout="paged", prefill_chunk=64)
    assert ServingConfig.from_json(cfg.to_json()) == cfg


def test_serving_config_every_field_is_a_flag():
    ap = argparse.ArgumentParser()
    ServingConfig.add_args(ap)
    flags = {a.dest for a in ap._actions if a.dest != "help"}
    assert flags == {f.name for f in dataclasses.fields(ServingConfig)}


def test_normalized_gates_paged_features():
    bad = ServingConfig(disaggregate=True, spec_decode=True,
                        kv_dtype="fp8", host_mem_gb=1.0,
                        cluster_prefix=True, prefix_hit_rate=0.5)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ok = bad.normalized()
    assert len(w) == 5
    assert not ok.disaggregate and not ok.spec_decode
    assert ok.kv_dtype == "auto" and ok.host_mem_gb == 0.0
    assert not ok.cluster_prefix and ok.prefix_hit_rate == 0.0
    # idempotent: a consistent config passes through silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert ok.normalized() == ok


def test_max_len_rounds_to_blocks():
    cfg = ServingConfig(prompt_len=10, out_len=5, cache_layout="paged",
                        block_size=16)
    assert cfg.max_len() % 16 == 0
    cont = ServingConfig(prompt_len=10, out_len=5)
    assert cont.max_len() == 10 + 8 + 5


def test_guard_layers_pins_both_ends():
    cfg = ServingConfig(kv_guard_layers=2)
    assert cfg.guard_layers(8) == [0, 1, 6, 7]
    assert cfg.guard_layers(2) == [0, 1]      # clamped to half the stack
    assert ServingConfig().guard_layers(8) == []
