"""HexGen core: cost model, DP optimality vs brute force, genetic search,
memory constraints, case-study orderings (paper Fig. 1)."""
import itertools

import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import slo_sim
from repro.core.dp_layout import dp_assign, optimize_pipeline, _even_split
from repro.core.genetic import kmeans_init, mutate, search
from repro.core.scheduler import schedule

TASK = cm.Task(batch=1, s_in=128, s_out=64)
LLAMA = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                    paper_exact=True)


def test_case_study_fig1_orderings():
    c = cl.case_study_cluster()
    # pure TP=8 and even PP=8 violate memory (A4000-16G) -- the paper's OOMs
    assert not cm.mem_ok(c, list(range(8)), 80, LLAMA, TASK)
    assert not cm.mem_ok(c, [6], 10, LLAMA, TASK)
    # orderings: asymmetric [4,2,2]/48-20-12 beats PP8-proportional and
    # PP2xTP4 cross-machine
    pp8 = cm.pipeline_cost(c, [[d] for d in range(8)],
                           [14, 14, 14, 14, 7, 7, 5, 5], LLAMA, TASK)
    pp2tp4 = cm.pipeline_cost(c, [[0, 1, 2, 3], [4, 5, 6, 7]], [56, 24],
                              LLAMA, TASK)
    hexgen = cm.pipeline_cost(c, [[0, 1, 2, 3], [4, 5], [6, 7]],
                              [48, 20, 12], LLAMA, TASK)
    assert hexgen < pp8 < pp2tp4
    assert pp8 / hexgen > 1.5          # paper reports ~2x


def test_tp_comm_zero_for_single_gpu():
    c = cl.case_study_cluster()
    assert cm.comm_tp_cost(c, [0], 10, LLAMA, TASK) == 0.0


def test_comm_tp_grows_with_slow_links():
    full = cl.hetero_full_price()
    # same-machine TP vs cross-region TP (Iceland + Illinois)
    same = cm.comm_tp_cost(full, [0, 1], 10, LLAMA, TASK)
    mach = full.machines()
    cross = cm.comm_tp_cost(full, [mach[0][0], mach[5][0]], 10, LLAMA, TASK)
    assert cross > 100 * same


def test_dp_matches_bruteforce_tiny():
    """On a tiny pool, Algorithm 1 == exhaustive enumeration."""
    c = cl.case_study_cluster()           # machines: 4xA6000, 2xA5000, 2xA4000
    devs = list(range(8))
    split = [40, 40]
    got = dp_assign(c, devs, split, LLAMA, TASK, tp_candidates=(1, 2, 4))
    assert got is not None
    got_cost = cm.pipeline_cost(c, got, split, LLAMA, TASK)

    pools = {0: [0, 1, 2, 3], 1: [4, 5], 2: [6, 7]}
    best = float("inf")
    for m1, m2 in itertools.product(pools, repeat=2):
        for t1 in (1, 2, 4):
            for t2 in (1, 2, 4):
                if m1 == m2 and t1 + t2 > len(pools[m1]):
                    continue
                if t1 > len(pools[m1]) or t2 > len(pools[m2]):
                    continue
                s1 = pools[m1][:t1]
                s2 = [d for d in pools[m2] if d not in s1][:t2]
                if len(s2) < t2:
                    continue
                cost = cm.pipeline_cost(c, [s1, s2], split, LLAMA, TASK)
                best = min(best, cost)
    assert got_cost <= best + 1e-9


def test_dp_respects_memory():
    c = cl.case_study_cluster()
    plan = optimize_pipeline(c, list(range(8)), LLAMA, TASK)
    assert plan is not None
    for st_, l in zip(plan.stages, plan.layer_split):
        assert cm.mem_ok(c, st_.device_ids, l, LLAMA, TASK)


def test_optimize_pipeline_infeasible_pool():
    c = cl.case_study_cluster()
    # 2 x A4000 (32 GB total) cannot hold a 140 GB model
    assert optimize_pipeline(c, [6, 7], LLAMA, TASK) is None


def test_even_split_sums():
    for L in (7, 80, 32):
        for S in (1, 2, 3, 5):
            sp = _even_split(L, S)
            assert sum(sp) == L and len(sp) == S
            assert max(sp) - min(sp) <= 1


def test_kmeans_init_groups_by_region():
    rng = np.random.default_rng(0)
    full = cl.hetero_full_price()
    seeds = kmeans_init(full, rng)
    assert seeds
    for ind in seeds:
        devs = sorted(d for g in ind for d in g)
        assert devs == list(range(len(full)))       # partitions the pool


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_mutations_preserve_partition(seed):
    rng = np.random.default_rng(seed)
    full = cl.hetero_half_price()
    ind = kmeans_init(full, rng)[0]
    for _ in range(5):
        ind = mutate(ind, rng)
        devs = sorted(d for g in ind for d in g)
        assert devs == list(range(len(full)))


def test_search_beats_random_mutation():
    half = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    hx = schedule(half, "llama2-70b", task, deadline=8.0, rate=4.0,
                  iters=12, seed=0, paper_exact=True)
    rnd = schedule(half, "llama2-70b", task, deadline=8.0, rate=4.0,
                   iters=12, seed=0, mutation="random", paper_exact=True)
    assert hx.attainment >= rnd.attainment


def test_assignment_valid_and_disjoint():
    half = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    res = schedule(half, "llama2-70b", task, deadline=10.0, rate=2.0,
                   iters=8, seed=1, paper_exact=True)
    res.assignment.validate(80)          # raises on overlap / bad layer sums
    assert res.assignment.num_replicas >= 1


def test_generalized_profile_all_archs():
    """The generalized cost model covers every assigned architecture."""
    pool = cl.tpu_mixed_slices()
    task = cm.Task(batch=1, s_in=256, s_out=32)
    for arch in ("jamba-v0.1-52b", "granite-moe-3b-a800m", "xlstm-125m",
                 "whisper-base"):
        prof = cm.ModelProfile.from_config(get_config(arch))
        assert prof.params_per_layer > 0
        assert prof.flops_per_layer_per_token > 0
        plan = optimize_pipeline(pool, list(range(len(pool))), prof, task)
        if arch in ("xlstm-125m", "whisper-base"):   # tiny models must fit
            assert plan is not None


# ---------------------------------------------------------------------------
# SLO simulator properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 8.0), st.integers(0, 100))
def test_attainment_monotone_in_deadline(rate, seed):
    reps = [slo_sim.ReplicaModel(latency=1.0, bottleneck=0.5)]
    a1 = slo_sim.simulate(reps, rate, 1.0, duration=30, seed=seed)
    a2 = slo_sim.simulate(reps, rate, 5.0, duration=30, seed=seed)
    assert a2 >= a1


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 100))
def test_attainment_monotone_in_replicas(n, seed):
    rep = slo_sim.ReplicaModel(latency=1.0, bottleneck=1.0)
    a1 = slo_sim.simulate([rep] * n, 4.0, 2.0, duration=30, seed=seed)
    a2 = slo_sim.simulate([rep] * (n + 2), 4.0, 2.0, duration=30, seed=seed)
    assert a2 >= a1 - 1e-9


# ---------------------------------------------------------------------------
# Acceptance-aware speculative decoding (serving.spec)
# ---------------------------------------------------------------------------

def test_expected_commit_per_step_bounds_and_monotonicity():
    assert cm.expected_commit_per_step(0.0, 4) == 1.0    # nothing accepted
    assert cm.expected_commit_per_step(1.0, 4) == 5.0    # everything accepted
    assert cm.expected_commit_per_step(0.5, 0) == 1.0    # plain decode
    prev = 0.0
    for a in (0.1, 0.3, 0.5, 0.7, 0.9):
        e = cm.expected_commit_per_step(a, 4)
        assert 1.0 < e < 5.0 and e > prev
        prev = e
    assert cm.expected_commit_per_step(0.8, 6) \
        > cm.expected_commit_per_step(0.8, 2)


def test_best_spec_k_deeper_for_slower_replica():
    """The acceptance-aware depth choice: the draft cost is absolute, so
    a slow replica amortizes each draft over a bigger saved target step
    and speculates DEEPER — the heterogeneity lever."""
    fast = cm.best_spec_k(1.0, 0.5, 0.8, max_k=8)
    slow = cm.best_spec_k(10.0, 0.5, 0.8, max_k=8)
    assert slow > fast >= 1
    assert cm.best_spec_k(1.0, 0.0, 0.8, max_k=8) == 8   # free drafts
    assert cm.best_spec_k(1.0, 0.5, 0.0, max_k=8) == 0   # hopeless drafts
    assert cm.spec_step_cost(3.0, 0.7, 0.6, 0) == 3.0    # k=0 = plain cost


def test_choose_spec_ks_slowed_replica_speculates_deeper():
    """genetic.choose_spec_ks on a fast/slow replica pair: the slowed-down
    replica gets the deeper per-replica spec-k, and the decode multiplier
    scales ONLY the decode phase of the simulated worker."""
    from repro.core.genetic import choose_spec_ks
    fast = slo_sim.PhasedReplicaModel(
        prefill_latency=1.0, prefill_bottleneck=0.5,
        decode_latency=2.0, decode_bottleneck=2.0)
    slow = slo_sim.PhasedReplicaModel(
        prefill_latency=1.0, prefill_bottleneck=0.5,
        decode_latency=20.0, decode_bottleneck=20.0)
    ks, mults = choose_spec_ks([fast, slow], alpha=0.8,
                               draft_step_cost=0.02, s_out=64, max_k=8)
    assert ks[1] > ks[0] >= 1
    assert all(0.0 < m <= 1.0 + 1e-9 for m in mults)
    scaled = slow.with_spec(mults[1])
    assert scaled.prefill_latency == slow.prefill_latency
    assert scaled.prefill_bottleneck == slow.prefill_bottleneck
    assert scaled.decode_bottleneck < slow.decode_bottleneck


def test_spec_multi_token_commits_improve_attainment():
    """slo_sim workers consuming multi-token commits: a decode-bound
    replica that misses its deadline at one token per step makes it once
    speculation shrinks time per committed token."""
    rep = slo_sim.PhasedReplicaModel(
        prefill_latency=0.2, prefill_bottleneck=0.2,
        decode_latency=2.0, decode_bottleneck=1.0)
    base = slo_sim.simulate([rep.colocated()], 2.0, 1.5, duration=30)
    spec = slo_sim.simulate([rep.with_spec(0.4).colocated()], 2.0, 1.5,
                            duration=30)
    assert spec > base


def test_schedule_threads_spec_ks():
    half = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    res = schedule(half, "llama2-70b", task, deadline=8.0, rate=4.0,
                   iters=6, seed=0, paper_exact=True, spec_decode=True,
                   spec_alpha=0.8, spec_draft_cost=1e-4, max_spec_k=6)
    assert res.plan.spec_ks is not None
    assert len(res.plan.spec_ks) == res.assignment.num_replicas
    assert all(0 <= k <= 6 for k in res.plan.spec_ks)
    # without spec_decode the dimension stays un-searched (None view)
    res0 = schedule(half, "llama2-70b", task, deadline=8.0, rate=4.0,
                    iters=6, seed=0, paper_exact=True)
    assert res0.plan.spec_ks is None


def test_peak_rate_bisection():
    reps = [slo_sim.ReplicaModel(latency=0.5, bottleneck=0.25)] * 2
    peak = slo_sim.peak_rate_for_attainment(reps, deadline=1.0, target=0.99,
                                            duration=30)
    assert 1.0 < peak < 20.0
    # ~2 replicas x 4 req/s capacity each
