"""Infrastructure: HLO analyzer trip-count accounting, sharding spec trees,
checkpoint round-trip, data pipeline determinism, optimizer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import hlo_analysis
from repro.models import model as M
from repro.models import shardings
from repro.training import optimizer
from repro.training.data import DataConfig, SyntheticStream

KEY = jax.random.PRNGKey(0)


def test_hlo_analyzer_counts_scan_trips():
    W = jax.random.normal(KEY, (64, 64))

    def body(x, _):
        return jnp.tanh(x @ W), None

    x0 = jax.random.normal(KEY, (4, 64))
    f = jax.jit(lambda x: jax.lax.scan(body, x, None, length=10)[0])
    c = hlo_analysis.analyze(f.lower(x0).compile().as_text())
    expect = 10 * 2 * 4 * 64 * 64
    assert 0.9 * expect <= c.flops <= 1.3 * expect

    # nested scan multiplies
    def outer(x, _):
        return jax.lax.scan(body, x, None, length=5)[0], None

    f2 = jax.jit(lambda x: jax.lax.scan(outer, x, None, length=10)[0])
    c2 = hlo_analysis.analyze(f2.lower(x0).compile().as_text())
    assert 0.9 * 5 * expect <= c2.flops <= 1.3 * 5 * expect


def test_hlo_shape_parse():
    b, dims = hlo_analysis._shape_info("bf16[16,4096]{1,0}")
    assert b == 16 * 4096 * 2 and dims == [16, 4096]
    b, _ = hlo_analysis._shape_info("(f32[8], s32[], pred[2,2])")
    assert b == 32 + 4 + 4


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-v0.1-52b",
                                  "phi3.5-moe-42b-a6.6b", "xlstm-125m",
                                  "whisper-base", "paligemma-3b"])
def test_param_specs_cover_tree(arch):
    """Spec tree is congruent with the param tree and every spec rank
    matches its leaf rank."""
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda: M.init_params(cfg, KEY))
    specs = shardings.param_specs(cfg, params, tp=2)
    jax.tree.map(lambda l, s: None, params, specs)       # same structure
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
                or type(x).__name__ == "PartitionSpec")[0]):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


def test_cache_specs_cover_tree():
    cfg = get_config("jamba-v0.1-52b").reduced()
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 4, 32))
    specs = shardings.cache_specs(cfg, cache, tp=2, data_axis="data")
    jax.tree.map(lambda l, s: None, cache, specs)


def test_checkpoint_roundtrip():
    from repro.checkpoint import ckpt
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    opt = optimizer.init(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, params, opt, extra={"loss": 1.5})
        assert ckpt.latest_step(d) == 7
        p2, o2, meta = ckpt.restore(d, 7, params, opt)
        assert meta["step"] == 7 and meta["loss"] == 1.5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_deterministic_and_learnable_structure():
    cfg = DataConfig(vocab_size=512, seq_len=64, batch_size=4, seed=3)
    s1, s2 = SyntheticStream(cfg), SyntheticStream(cfg)
    b1, b2 = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    # tokens restricted to the active Markov set
    assert len(np.unique(b1["tokens"])) <= cfg.markov_states


def test_adamw_decreases_quadratic():
    cfg = optimizer.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                                weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = optimizer.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state = optimizer.apply(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule():
    cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optimizer.lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] == pytest.approx(cfg.min_lr_frac)
