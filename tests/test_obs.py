"""HexTrace observability (repro.obs): span tracing, metrics, calibration.

Four bars:

  * the tracer is PURE OBSERVATION — mixed prefix / chunked / spec /
    preemption / disaggregated traffic is token-identical with tracing on
    or off, and two seeded ``VirtualClock`` runs export byte-identical
    Chrome traces;
  * trace-derived request timestamps (``first_token_time``,
    ``prefill_finish_time``) equal the engines' inline stamps, and
    chunked-prefill TTFT equals the first decode-span start;
  * ``ServeStats.merge`` / ``publish`` / ``from_metrics`` aggregate and
    round-trip counters, distributions and attainment correctly, down to
    empty/degenerate inputs;
  * the calibration layer turns predicted-vs-observed phase costs into
    per-(replica, phase) error rows that make ``DriftDetector`` fire its
    model-error signal.
"""
import json

import jax
import numpy as np
import pytest

from repro.core.resched import DriftDetector
from repro.obs.calibration import (CostCalibrator, PHASES,
                                   predictions_from_phase_costs)
from repro.obs.metrics import (DEFAULT_BUCKETS, Histogram, MetricsRegistry,
                               phase_histograms_from_trace)
from repro.obs.report import main as report_main
from repro.obs.trace import (NULL_TRACER, SPAN_NAMES, Tracer,
                             validate_chrome_trace)
from repro.serving.loop import ServeStats, VirtualClock, run_serve_loop

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_complete_instant_and_span():
    clk = VirtualClock()
    tr = Tracer(clk)
    tr.complete("decode", 0.5, ts=1.0, pid=2, tid=1, tokens=3)
    tr.instant("preempt", ts=1.5, pid=2, rid=7)
    clk.sleep_until(2.0)
    with tr.span("iteration", pid=2):
        clk.tick(0.25)
    assert [e["name"] for e in tr.events] == ["decode", "preempt",
                                              "iteration"]
    dec, ins, it = tr.events
    assert dec["ph"] == "X" and dec["dur"] == 0.5 and \
        dec["args"]["tokens"] == 3
    assert ins["ph"] == "i" and "dur" not in ins
    assert it["ph"] == "X" and it["ts"] == 2.0 and it["dur"] == 0.25
    obj = tr.to_chrome()
    assert validate_chrome_trace(obj) == []
    # µs conversion
    assert obj["traceEvents"][0]["ts"] == 1_000_000
    assert obj["traceEvents"][0]["dur"] == 500_000


def test_tracer_dumps_is_deterministic():
    def build():
        tr = Tracer(VirtualClock())
        tr.complete("prefill", 0.125, ts=0.0, pid=0, tokens=17)
        tr.instant("preempt", ts=0.5, pid=1, slot=2, rid=4)
        return tr
    assert build().dumps() == build().dumps()
    # bytes, not just structure: key order and separators are pinned
    assert '"name":"prefill"' in build().dumps()


def test_unclosed_span_fails_validation():
    tr = Tracer(VirtualClock())
    sp = tr.begin("serve")  # repro: noqa[span-pairing] (deliberate leak)
    errs = validate_chrome_trace(tr.to_chrome())
    assert any("never ended" in e for e in errs)
    tr.end(sp)
    assert validate_chrome_trace(tr.to_chrome()) == []
    assert validate_chrome_trace(tr.to_chrome(),
                                 require_spans=["decode"]) != []


def test_validate_rejects_malformed_events():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "X"}]}) != []
    bad_ph = {"traceEvents": [{"name": "x", "ph": "Z", "ts": 0,
                               "pid": 0, "tid": 0}]}
    assert any("unknown phase" in e for e in validate_chrome_trace(bad_ph))


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    NULL_TRACER.complete("decode", 1.0)
    NULL_TRACER.instant("preempt")
    sp = NULL_TRACER.begin("serve")
    NULL_TRACER.end(sp)
    NULL_TRACER.mark(1, "first_token", 0.5)
    assert NULL_TRACER.events == [] and NULL_TRACER.request_marks == {}


def test_marks_first_occurrence_wins():
    tr = Tracer(VirtualClock())
    tr.mark(1, "first_token", 2.0)
    tr.mark(1, "first_token", 5.0)      # later stamp must not overwrite
    class R:
        rid = 1
        first_token_time = None
        prefill_finish_time = None
    r = R()
    tr.apply_marks([r])
    assert r.first_token_time == 2.0 and r.prefill_finish_time is None


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_instruments_and_labels():
    reg = MetricsRegistry()
    reg.counter("served", replica=0).inc(3)
    reg.counter("served", replica=1).inc()
    reg.gauge("occupancy", stage=0).set(5)
    reg.gauge("occupancy", stage=0).set(2)       # peak survives
    h = reg.histogram("lat")
    for v in (0.01, 0.2, 3.0):
        h.observe(v)
    assert reg.value("served", replica=0) == 3
    assert reg.value("served", replica=1) == 1
    assert reg.value("served", replica=9) is None
    assert reg.total("served") == 4
    g = reg.gauge("occupancy", stage=0)
    assert g.value == 2 and g.peak == 5
    assert h.count == 3 and h.mean == pytest.approx(3.21 / 3)
    assert h.min == 0.01 and h.max == 3.0
    assert h.quantile(0.5) in DEFAULT_BUCKETS


def test_histogram_bucket_edges_and_overflow():
    h = Histogram(buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 1.5, 99.0):
        h.observe(v)
    assert h.counts == [2, 1, 1]         # <=1, <=2, +Inf overflow
    assert h.quantile(1.0) == 99.0


def test_registry_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("served", replica=0).inc(7)
    reg.gauge("occ", stage=1).set(9)
    reg.gauge("occ", stage=1).set(4)
    reg.histogram("lat", phase="decode").observe(0.3)
    p = tmp_path / "metrics.jsonl"
    reg.to_jsonl(str(p))
    back = MetricsRegistry.from_jsonl(str(p))
    assert back.collect() == reg.collect()


# ---------------------------------------------------------------------------
# ServeStats: merge / publish / from_metrics (satellite)
# ---------------------------------------------------------------------------

def _stats(n, lats, att, thpt, **kw):
    s = ServeStats(latencies=list(lats), attainment=att, throughput=thpt,
                   n_requests=n)
    for k, v in kw.items():
        setattr(s, k, v)
    return s


def test_merge_empty_and_single():
    z = ServeStats.merge([])
    assert z.latencies == [] and z.attainment == 1.0 and z.throughput == 0.0
    one = _stats(3, [1.0, 2.0], 0.5, 4.0, preemptions=2)
    m = ServeStats.merge([one])
    assert m.latencies == [1.0, 2.0] and m.attainment == 0.5
    assert m.throughput == 4.0 and m.preemptions == 2 and m.n_requests == 3


def test_merge_weights_attainment_and_sums_counters():
    a = _stats(8, [1.0], 1.0, 2.0, prefix_hits=3, iterations=10)
    b = _stats(2, [5.0, 6.0], 0.0, 1.0, prefix_hits=1, iterations=4)
    m = ServeStats.merge([a, b])
    assert m.latencies == [1.0, 5.0, 6.0]
    assert m.attainment == pytest.approx(0.8)    # (8*1 + 2*0) / 10
    assert m.throughput == pytest.approx(3.0)
    assert m.prefix_hits == 4 and m.iterations == 14 and m.n_requests == 10


def test_merge_degenerate_zero_request_parts():
    a = _stats(0, [], 1.0, 0.0)
    b = _stats(0, [], 1.0, 0.0)
    m = ServeStats.merge([a, b])
    assert m.attainment == 1.0 and m.n_requests == 0
    # a zero-request part must not dilute a real part's attainment
    m2 = ServeStats.merge([a, _stats(4, [1.0], 0.25, 1.0)])
    assert m2.attainment == pytest.approx(0.25)


def test_publish_from_metrics_roundtrip():
    reg = MetricsRegistry()
    s = _stats(5, [0.02, 0.3], 0.8, 2.5, preemptions=3, spec_steps=7)
    s.queue_delays = [0.004, 0.04]
    s.publish(reg)
    assert reg.value("serve_preemptions") == 3
    assert reg.value("serve_spec_steps") == 7
    assert reg.value("serve_attainment") == pytest.approx(0.8)
    back = ServeStats.from_metrics(reg)
    assert back.preemptions == 3 and back.spec_steps == 7
    assert back.n_requests == 5
    assert back.attainment == pytest.approx(0.8)
    assert back.throughput == pytest.approx(2.5)
    # distributions come back at bucket resolution: counts survive exactly
    assert len(back.latencies) == 2 and len(back.queue_delays) == 2


# ---------------------------------------------------------------------------
# Calibration + DriftDetector model-error signal
# ---------------------------------------------------------------------------

def test_calibrator_report_and_units():
    cal = CostCalibrator()
    cal.predict(0, "decode", 1.0)
    cal.observe(0, "decode", 1.5)
    cal.observe(0, "decode", 0.9)
    cal.observe(1, "prefill", 6.0, units=12)     # per-token phase
    rows = cal.report()
    assert [(r["replica"], r["phase"]) for r in rows] == \
        [(0, "decode"), (1, "prefill")]
    dec, pre = rows
    assert dec["observed"] == pytest.approx(1.2) and dec["spans"] == 2
    assert dec["rel_err"] == pytest.approx(0.2)
    assert pre["observed"] == pytest.approx(0.5)
    assert pre["predicted"] is None and pre["rel_err"] is None
    assert "calibration:" in cal.summary()


def test_calibrator_observe_trace_and_metrics_agree():
    tr = Tracer(VirtualClock())
    tr.complete("prefill", 4.0, ts=0.0, pid=0, tokens=8)
    tr.complete("decode", 1.0, ts=1.0, pid=0, tokens=3)
    tr.complete("iteration", 9.0, ts=1.0, pid=0)   # excluded from PHASES
    assert "iteration" not in PHASES
    a = CostCalibrator()
    a.observe_trace(tr)
    reg = MetricsRegistry()
    phase_histograms_from_trace(tr, reg)
    b = CostCalibrator()
    b.observe_metrics(reg)
    ra, rb = a.report(), b.report()
    assert [(r["phase"], r["observed"]) for r in ra] == \
        [(r["phase"], r["observed"]) for r in rb]
    # prefill normalized per token, decode per span
    by = {r["phase"]: r for r in ra}
    assert by["prefill"]["observed"] == pytest.approx(0.5)
    assert by["decode"]["observed"] == pytest.approx(1.0)


def test_phase_costs_predictions_helper():
    from repro.core.cost_model import PhaseCosts
    pc = PhaseCosts(prefill_latency=2.0, prefill_bottleneck=1.5,
                    decode_latency=0.25, decode_bottleneck=0.2)
    assert pc.as_dict()["decode_latency"] == 0.25
    cal = CostCalibrator()
    predictions_from_phase_costs(cal, 3, pc, s_in=8)
    cal.observe(3, "prefill", 1.0, units=4)
    cal.observe(3, "decode", 0.25)
    rows = {r["phase"]: r for r in cal.report()}
    assert rows["prefill"]["predicted"] == pytest.approx(0.25)
    assert rows["decode"]["rel_err"] == pytest.approx(0.0)


def test_drift_detector_model_error_fires_and_reanchors():
    det = DriftDetector(rate=1.0, model_error_threshold=0.5,
                        model_error_min=1)
    det.observe_model_error("decode", 1.0, 1.2)      # 20% — in band
    assert det.poll(0.0) is None
    det.observe_model_error("decode", 1.0, 3.0)      # blows the band
    sig = det.poll(1.0)
    assert sig is not None and sig.kind == "model_error"
    assert sig.phase == "decode" and sig.factor > 1.5
    assert "model_error" in sig.describe()
    assert det.poll(2.0) is None                     # re-anchored: once


def test_model_error_is_lowest_priority():
    det = DriftDetector(rate=1.0, model_error_threshold=0.1,
                        model_error_min=1)
    det.observe_model_error("prefill", 1.0, 9.0)
    det.observe_death(frozenset({0}))
    assert det.poll(0.0).kind == "replica_death"     # death first
    assert det.poll(0.0).kind == "model_error"       # then calibration


def test_calibrator_feed_reaches_detector():
    cal = CostCalibrator()
    cal.predict(0, "decode", 1.0)
    cal.observe(0, "decode", 2.0)
    cal.observe(0, "prefill", 1.0)                   # no prediction: not fed
    det = DriftDetector(rate=1.0, model_error_threshold=0.5,
                        model_error_min=1)
    assert cal.feed(det) == 1
    assert det.poll(0.0).kind == "model_error"


# ---------------------------------------------------------------------------
# End-to-end: traced serving is pure observation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_setup():
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.pipeline import AsymmetricPipeline

    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe(split=None):
        split = split if split is not None else [1, L - 1]
        return AsymmetricPipeline(cfg, params, split, [[dev]] * len(split))
    return cfg, pipe


def _mixed_reqs(cfg, seed):
    from repro.serving.request import Request
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
    reqs = []
    for i in range(7):
        if i % 2 == 0:
            tail = rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 8))
                                ).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=int(rng.integers(8, 16))
                                  ).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(8, 13)),
                            arrival=0.1 * i))
    return reqs


def _serve_mixed(pipe, cfg, seed, *, tracer=None, kvsan=False):
    from repro.serving.continuous import PagedPipelineBatcher
    from repro.serving.spec import SpecConfig

    b = PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             stage_blocks=[9, 9], admit_headroom=0,
                             prefix_caching=True, prefill_chunk=8,
                             spec=SpecConfig(k=2), kvsan=kvsan)
    if tracer is not None:
        b.tracer = tracer
    reqs = _mixed_reqs(cfg, seed)
    stats = b.serve(reqs, deadline=1e9)
    return b, reqs, stats


@pytest.mark.parametrize("kvsan", [False, True])
def test_traced_serving_token_identical(paged_setup, kvsan):
    cfg, pipe = paged_setup
    _, reqs_off, stats_off = _serve_mixed(pipe, cfg, 3, kvsan=kvsan)
    tr = Tracer()
    _, reqs_on, stats_on = _serve_mixed(pipe, cfg, 3, tracer=tr,
                                        kvsan=kvsan)
    # the traffic genuinely mixes the lifecycle phases
    assert stats_off.prefix_hits > 0 and stats_off.spec_steps > 0
    assert stats_off.preemptions > 0
    for ro, rt in zip(reqs_off, reqs_on):
        assert list(ro.output) == list(rt.output), ro.rid
        # trace-derived timestamps equal the engines' inline stamps...
        assert rt.first_token_time == ro.first_token_time, ro.rid
        # ...and fill in what the untraced colocated path never stamps
        # (inline stamping of prefill_finish only exists on the disagg
        # handoff path — the satellite's point: the trace is the source
        # of truth for lifecycle timestamps when tracing is on)
        assert rt.prefill_finish_time is not None, ro.rid
        assert rt.prefill_finish_time <= rt.first_token_time, ro.rid
        if ro.prefill_finish_time is not None:
            assert rt.prefill_finish_time == ro.prefill_finish_time
    assert stats_on.preemptions == stats_off.preemptions
    names = {e["name"] for e in tr.events}
    # spec replaces the plain decode step with propose/verify spans; the
    # chunked-prefill TTFT test covers the plain "decode" span
    for want in ("serve", "queue_wait", "iteration", "prefill",
                 "spec_propose", "spec_verify", "preempt"):
        assert want in names, (want, sorted(names))
    assert set(names) <= set(SPAN_NAMES) | {"serve", "spec_draft"}
    assert validate_chrome_trace(tr.to_chrome()) == []


def test_trace_bytes_identical_across_seeded_runs(paged_setup):
    cfg, pipe = paged_setup
    tr1 = Tracer()
    _serve_mixed(pipe, cfg, 11, tracer=tr1)
    tr2 = Tracer()
    _serve_mixed(pipe, cfg, 11, tracer=tr2)
    assert tr1.dumps() == tr2.dumps()
    assert len(tr1.events) > 20


def test_chunked_prefill_ttft_equals_first_decode_span(paged_setup):
    """Satellite regression: with chunked prefill, the request's TTFT is
    exactly the start of the first decode span — not the end of the first
    chunk, not the prefill-finish mark."""
    from repro.serving.continuous import PagedPipelineBatcher
    from repro.serving.request import Request

    cfg, pipe = paged_setup
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=20
                                             ).astype(np.int32),
                  max_new_tokens=4, arrival=0.0)
    tr = Tracer()
    b = PagedPipelineBatcher(pipe(), n_slots=2, max_len=48, block_size=8,
                             prefill_chunk=8)
    b.tracer = tr
    b.serve([req], deadline=1e9)
    assert list(req.output) and req.first_token_time is not None
    decode_ts = [e["ts"] for e in tr.events if e["name"] == "decode"]
    prefill_evs = [e for e in tr.events if e["name"] == "prefill"]
    assert len(prefill_evs) >= 3                 # 20 tokens / 8-chunks
    assert req.first_token_time == min(decode_ts)
    assert req.prefill_finish_time is not None
    assert req.prefill_finish_time <= req.first_token_time


def test_disagg_migration_spans(paged_setup):
    from repro.serving.continuous import PagedPipelineBatcher
    from repro.serving.disagg import KVLink, wire_disaggregation
    from repro.serving.request import Request

    cfg, pipe = paged_setup
    L = len(pipe().layer_split) if hasattr(pipe(), "layer_split") else 2

    def reqs():
        rng = np.random.RandomState(3)
        return [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size, size=8 + i
                                           ).astype(np.int32),
                        max_new_tokens=5, arrival=0.4 * i)
                for i in range(4)]

    def serve(tracer):
        p = PagedPipelineBatcher(pipe(), n_slots=4, max_len=48,
                                 block_size=8, role="prefill",
                                 replica_id=0)
        d = PagedPipelineBatcher(pipe(), n_slots=4, max_len=48,
                                 block_size=8, role="decode", replica_id=1)
        disp = wire_disaggregation([p, d], ["prefill", "decode"], KVLink())
        rs = reqs()
        if tracer is not None:
            p.tracer = d.tracer = disp.tracer = tracer
        stats = run_serve_loop([p, d], rs, deadline=1e9,
                               clock=VirtualClock(), tracer=tracer)
        return rs, stats

    rs_off, _ = serve(None)
    tr = Tracer()
    rs_on, stats = serve(tr)
    assert stats.migrations > 0
    for ro, rt in zip(rs_off, rs_on):
        assert list(ro.output) == list(rt.output), ro.rid
    migs = [e for e in tr.events if e["name"] == "kv_migration"]
    assert len(migs) == stats.migrations
    assert all(e["args"]["dst"] == 1 and e["pid"] == 0 for e in migs)


def test_loop_metrics_publication(paged_setup):
    cfg, pipe = paged_setup
    from repro.serving.continuous import PagedPipelineBatcher

    b = PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             prefix_caching=True)
    reqs = _mixed_reqs(cfg, 3)
    reg = MetricsRegistry()
    stats = run_serve_loop([b], reqs, deadline=1e9, clock=VirtualClock(),
                           metrics=reg)
    # per-replica counter deltas + the final ServeStats publication
    assert reg.value("serve_prefix_hits", replica="0") == \
        stats.prefix_hits > 0
    assert reg.total("serve_n_requests") == len(reqs)
    # engine gauges (metrics_gauges port): pool occupancy high-water
    g = reg.gauge("kv_pool_peak_blocks", replica="0", stage="1")
    assert g.value > 0
    back = ServeStats.from_metrics(reg)
    assert back.prefix_hits == stats.prefix_hits
    assert back.attainment == pytest.approx(stats.attainment)


def test_untraced_serving_emits_nothing(paged_setup):
    cfg, pipe = paged_setup
    b, _, _ = _serve_mixed(pipe, cfg, 3)
    assert b.tracer is NULL_TRACER and NULL_TRACER.events == []


# ---------------------------------------------------------------------------
# Report CLI
# ---------------------------------------------------------------------------

def test_report_cli_valid_and_invalid(tmp_path, capsys, paged_setup):
    cfg, pipe = paged_setup
    tr = Tracer()
    _serve_mixed(pipe, cfg, 3, tracer=tr)
    reg = MetricsRegistry()
    phase_histograms_from_trace(tr, reg)
    trace_p = tmp_path / "trace.json"
    metrics_p = tmp_path / "metrics.jsonl"
    tr.write(str(trace_p))
    reg.to_jsonl(str(metrics_p))
    rc = report_main([str(metrics_p), "--trace", str(trace_p),
                      "--require-spans", "prefill,spec_verify"])
    out = capsys.readouterr().out
    assert rc == 0 and "trace OK" in out and "calibration" in out
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert report_main(["--trace", str(bad)]) == 1
