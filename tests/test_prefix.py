"""Copy-on-write prefix caching + chunked prefill on the paged engine.

Correctness bar (same as the paged refactor): warm-prefix serving and
chunked prefill must change WHERE prefill compute and cache bytes come
from, never what gets generated — outputs are token-identical to cold
one-shot serving on a multi-stage asymmetric pipeline. Host-side refcount
bookkeeping (PrefixIndex / BlockTable.writable) is checked against an
independent reference-count model under randomized match/alias/COW/release
interleavings: no block leaked, none double-freed.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_context_attention_pallas
from repro.models import model as M
from repro.serving.block_manager import (BlockPool, BlockTable, NULL_BLOCK,
                                         PrefixIndex, blocks_for_tokens,
                                         chunk_hashes)
from repro.serving.continuous import PagedPipelineBatcher, PipelineBatcher
from repro.serving.pipeline import AsymmetricPipeline, context_mode_supported
from repro.serving.request import Request, shared_prefix_workload

KEY = jax.random.PRNGKey(0)


def rn(i, *shape):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Host-side bookkeeping: chunk hashes, index, COW
# ---------------------------------------------------------------------------

def test_chunk_hashes_prefix_property():
    bs = 4
    a = np.array([1, 2, 3, 4, 5, 6, 7, 8, 9], np.int32)
    b = np.array([1, 2, 3, 4, 9, 9, 9, 9], np.int32)    # diverges in chunk 2
    ha, hb = chunk_hashes(a, bs), chunk_hashes(b, bs)
    assert len(ha) == 2 and len(hb) == 2                # full blocks only
    assert ha[0] == hb[0] and ha[1] != hb[1]
    # chained: equal chunk content under a different PREFIX hashes apart
    c = np.array([9, 9, 9, 9, 5, 6, 7, 8], np.int32)
    assert chunk_hashes(c, bs)[1] != ha[1]


def test_prefix_index_match_acquire_register_evict():
    pool = BlockPool(8, block_size=4)
    ix = PrefixIndex(pool)
    prompt = np.arange(12, dtype=np.int32)
    hs = chunk_hashes(prompt, 4)
    t = BlockTable(pool)
    assert t.allocate_tokens(12)
    assert ix.match_len(hs) == 0
    ix.register(hs, t.blocks)
    assert ix.match_len(hs) == 3
    assert all(pool.ref(b) == 2 for b in t.blocks)      # table + index
    # a second request aliases the whole indexed prefix
    t2 = BlockTable(pool, ix.acquire(hs))
    assert t2.blocks == t.blocks
    assert all(pool.ref(b) == 3 for b in t.blocks)
    # owners release: blocks stay resident (index ref), become evictable
    t.release()
    t2.release()
    assert pool.n_free == 4 and ix.n_evictable() == 3
    assert ix.match_len(hs) == 3                        # cache survived
    # pool pressure evicts LRU-first and unmaps — a registered chain is
    # touched head-most-recent, so eviction peels it from the TAIL and
    # the head stays matchable (chained-hash matches are head-first)
    assert ix.evict(2) == 2
    assert pool.n_free == 6
    assert ix.match_len(hs) == 1                        # head chunk survives
    ix.clear()
    assert pool.n_free == 7 and len(ix) == 0


def test_block_table_writable_cow():
    pool = BlockPool(5, block_size=4)
    t = BlockTable(pool)
    assert t.allocate_tokens(8)
    assert t.writable(0) is None                        # exclusive already
    f = t.fork()
    src = t.blocks[0]
    cow = t.writable(0)
    assert cow is not None and cow is not False
    assert cow == (src, t.blocks[0]) and t.blocks[0] != src
    assert pool.ref(src) == 1 and pool.ref(t.blocks[0]) == 1
    # drain the pool: a COW on the still-shared block 1 must fail gracefully
    f2 = t.fork()
    extra = pool.alloc(pool.n_free)
    assert pool.n_free == 0
    assert t.writable(1) is False
    for b in extra:
        pool.free(b)
    f.release()
    f2.release()
    t.release()
    assert pool.n_free == 4


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(1, 8), st.integers(0, 10 ** 6))
def test_prefix_refcount_invariants_property(n_usable, block_size, seed):
    """Random interleavings of admit(match/alias + alloc + register),
    COW-write, release, and evict against an independent model of who
    holds references: pool refcounts must equal table-holds + index-holds
    for every block, nothing leaks, nothing double-frees (BlockPool
    asserts), and draining everything returns the pool to full."""
    rng = np.random.RandomState(seed % (2 ** 31))
    pool = BlockPool(n_usable + 1, block_size)
    ix = PrefixIndex(pool)
    tables = []                     # live (table, hashes) pairs

    def check():
        holds = np.zeros(pool.n_blocks, np.int64)
        for t, _ in tables:
            for b in t.blocks:
                holds[b] += 1
        for b in ix._lru:
            holds[b] += 1
        for b in range(1, pool.n_blocks):
            assert pool.ref(b) == holds[b], (b, pool.ref(b), holds[b])
        assert pool.n_free == (pool.n_blocks - 1) - int(
            np.count_nonzero(holds[1:]))
        # the O(1) evictable counter must agree with a full scan
        assert ix.n_evictable() == sum(
            1 for bid in ix._lru if pool.ref(bid) == 1)

    for _ in range(30):
        op = rng.randint(4)
        if op == 0:                 # admit a prompt from a tiny alphabet
            n_tok = rng.randint(1, 3 * block_size + 2)
            prompt = rng.randint(0, 3, size=n_tok)
            hs = chunk_hashes(prompt, block_size)
            L = ix.match_len(hs)
            t = BlockTable(pool, ix.acquire(hs[:L]))
            if not t.allocate_tokens(n_tok):
                need = blocks_for_tokens(n_tok, block_size) - t.n_blocks
                ix.evict(need - pool.n_free)
                if not t.allocate_tokens(n_tok):
                    t.release()
                    continue
            ix.register(hs, t.blocks[:len(hs)])
            tables.append((t, hs))
        elif op == 1 and tables:    # COW-write a random block
            t, _ = tables[rng.randint(len(tables))]
            if t.blocks:
                bi = rng.randint(len(t.blocks))
                if pool.n_free == 0:
                    ix.evict(1)
                t.writable(bi)      # None/False/copy all legal
        elif op == 2 and tables:    # release a random request
            t, _ = tables.pop(rng.randint(len(tables)))
            t.release()
        else:                       # background eviction pressure
            ix.evict(rng.randint(1, 3))
        check()

    for t, _ in tables:
        t.release()
    ix.clear()
    assert pool.n_free == pool.n_blocks - 1


# ---------------------------------------------------------------------------
# Kernels: paged context attention (warm-prefix / chunked-prefill primitive)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_context_kernel_vs_ref(dtype):
    b, C, hq, hkv, d = 2, 8, 4, 2, 32
    bs, n_blocks, nb = 8, 16, 6
    q = rn(1, b, C, hq, d).astype(dtype)
    kp = rn(2, n_blocks, bs, hkv, d).astype(dtype)
    vp = rn(3, n_blocks, bs, hkv, d).astype(dtype)
    bt = jnp.asarray(np.array([[3, 1, 4, 7, 0, 0],
                               [5, 9, 2, 6, 8, 10]], np.int32))
    q_start = jnp.array([17, 40])           # mid-block and block-aligned
    kv_len = jnp.array([17 + 8, 40 + 5])    # row 1 carries 3 pad queries
    o1 = paged_context_attention_pallas(q, kp, vp, bt, q_start=q_start,
                                        kv_len=kv_len, interpret=True)
    o2 = ref.paged_context_attention_ref(q, kp, vp, bt, q_start=q_start,
                                         kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


def test_context_ref_degenerates_to_causal_prefill():
    """q_start == 0 with the chunk covering the whole cache reduces the
    context oracle to ordinary causal attention."""
    b, C, hq, hkv, d = 2, 8, 4, 2, 16
    q = rn(1, b, C, hq, d)
    k = rn(2, b, C, hkv, d)
    v = rn(3, b, C, hkv, d)
    lens = jnp.array([8, 5])
    o1 = ref.context_attention_ref(q, k, v, q_start=jnp.zeros(2, jnp.int32),
                                   kv_len=lens)
    o2 = ref.attention_ref(q, k, v, causal=True, kv_len=lens)
    for i, L in enumerate([8, 5]):
        np.testing.assert_allclose(np.asarray(o1)[i, :L],
                                   np.asarray(o2)[i, :L], atol=1e-6)


def test_ops_context_xla_matches_gathered_oracle():
    b, C, hq, hkv, d = 2, 4, 4, 2, 16
    bs, n_blocks = 8, 12
    q = rn(1, b, C, hq, d)
    kp = rn(2, n_blocks, bs, hkv, d)
    vp = rn(3, n_blocks, bs, hkv, d)
    bt = jnp.asarray(np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32))
    q_start = jnp.array([10, 0])
    kv_len = jnp.array([14, 4])
    o = ops.paged_context_attention(q, kp, vp, bt, q_start=q_start,
                                    kv_len=kv_len)
    want = ref.paged_context_attention_ref(q, kp, vp, bt, q_start=q_start,
                                           kv_len=kv_len)
    assert np.array_equal(np.asarray(o), np.asarray(want))


# ---------------------------------------------------------------------------
# Model level: chunked context prefill == one-shot prefill
# ---------------------------------------------------------------------------

def test_prefill_paged_context_chunked_equals_one_shot():
    cfg = get_config("granite-8b").reduced()
    assert context_mode_supported(cfg)
    params = M.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    n_slots, slot_len, bs = 2, 32, 8
    nbmax = slot_len // bs
    lens = np.array([13, 9], np.int32)
    toks = np.zeros((n_slots, 16), np.int32)
    for i in range(n_slots):
        toks[i, :lens[i]] = rng.randint(0, cfg.vocab_size, lens[i])

    scratch = M.init_cache(cfg, n_slots, slot_len)
    lg, scratch = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                            scratch, lens=jnp.asarray(lens))
    bt = (1 + np.arange(n_slots * nbmax, dtype=np.int32)
          ).reshape(n_slots, nbmax)
    pool_ref = {k: M.scatter_cache_rows_paged(
        M.init_paged_cache(cfg, 1 + n_slots * nbmax, bs, n_slots)[k],
        scratch[k], [0, 1], bt.reshape(-1), batch_axis=1) for k in scratch}

    # same prompts through TWO context chunks into fresh pages
    pool_ctx = M.init_paged_cache(cfg, 1 + n_slots * nbmax, bs, n_slots)
    c1 = np.array([8, 5], np.int32)
    _, pool_ctx = M.prefill_paged_context(
        cfg, params, jnp.asarray(toks[:, :8]), pool_ctx,
        np.zeros(2, np.int32), c1, jnp.asarray(bt))
    rem = lens - c1
    t2 = np.zeros((n_slots, int(rem.max())), np.int32)
    for i in range(n_slots):
        t2[i, :rem[i]] = toks[i, c1[i]:lens[i]]
    lg2, pool_ctx = M.prefill_paged_context(
        cfg, params, jnp.asarray(t2), pool_ctx, c1, rem, jnp.asarray(bt))

    assert (np.argmax(np.asarray(lg), -1)
            == np.argmax(np.asarray(lg2), -1)).all()
    pos = lens.copy()
    lg_a, lg_b = np.asarray(lg), np.asarray(lg2)
    for step in range(4):
        na = jnp.asarray(np.argmax(lg_a, -1).astype(np.int32))
        nb_ = jnp.asarray(np.argmax(lg_b, -1).astype(np.int32))
        assert np.array_equal(np.asarray(na), np.asarray(nb_)), step
        lg_a, pool_ref = M.decode_step_paged(cfg, params, na, pool_ref,
                                             jnp.asarray(pos),
                                             jnp.asarray(bt))
        lg_b, pool_ctx = M.decode_step_paged(cfg, params, nb_, pool_ctx,
                                             jnp.asarray(pos),
                                             jnp.asarray(bt))
        lg_a, lg_b = np.asarray(lg_a), np.asarray(lg_b)
        pos += 1


def test_copy_cache_pages_duplicates_attn_leaves_only():
    cfg = get_config("granite-8b").reduced()
    cache = M.init_paged_cache(cfg, 6, 4, 2)
    poked = {k: {n: (l.at[(0,) * l.ndim].add(1.0)
                     if n in ("k", "v") else l)
                 for n, l in sub.items()} for k, sub in cache.items()}
    # write something recognizable into page 2, copy 2 -> 4
    for k in poked:
        poked[k]["k"] = poked[k]["k"].at[:, 2].set(7.0)
    out = M.copy_cache_pages(poked, [2], [4])
    for k in out:
        np.testing.assert_array_equal(np.asarray(out[k]["k"][:, 4]),
                                      np.asarray(poked[k]["k"][:, 2]))


# ---------------------------------------------------------------------------
# End-to-end: warm-prefix / chunked serving == cold serving (2-stage pipe)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_cold():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    def mk_reqs():
        rng = np.random.RandomState(3)
        shared = rng.randint(0, cfg.vocab_size, size=17).astype(np.int32)
        reqs = []
        for i in range(4):
            tail = rng.randint(0, cfg.vocab_size,
                               size=3 + 2 * i).astype(np.int32)
            reqs.append(Request(rid=i,
                                prompt=np.concatenate([shared, tail]),
                                max_new_tokens=5, arrival=0.05 * i))
        # an exact duplicate with a BLOCK-ALIGNED length (24 = 3 * 8): the
        # full-hit path that re-runs only the last token and must
        # copy-on-write the shared tail block
        dup = np.concatenate([shared,
                              np.arange(7, dtype=np.int32)])
        assert len(dup) % 8 == 0
        reqs.append(Request(rid=8, prompt=dup, max_new_tokens=4,
                            arrival=0.3))
        # arrives after everything drained: matches rid 8's FULLY indexed
        # prompt (all 3 blocks), so only the last token re-runs — and its
        # K/V write lands in the shared tail block, forcing COW
        reqs.append(Request(rid=9, prompt=dup.copy(), max_new_tokens=4,
                            arrival=25.0))
        return reqs

    reqs_c = mk_reqs()
    PipelineBatcher(pipe(), n_slots=3, max_len=48).serve(reqs_c,
                                                         deadline=1e9)
    return cfg, params, pipe, mk_reqs, reqs_c


def test_warm_prefix_serving_bit_identical_and_counted(served_cold):
    cfg, params, pipe, mk_reqs, reqs_c = served_cold
    reqs_w = mk_reqs()
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8,
        prefix_caching=True).serve(reqs_w, deadline=1e9)
    for rc, rw in zip(reqs_c, reqs_w):
        assert list(rc.output) == list(rw.output), rc.rid
    assert stats.prefix_lookups == len(reqs_w)
    assert stats.prefix_hits >= 4            # every non-first rider hits
    assert stats.prefix_hit_tokens > 0
    assert stats.cow_copies >= 1             # the duplicate full hit
    # warm prefill touched far fewer tokens than the prompts contain
    total_prompt = sum(len(r.prompt) for r in reqs_w)
    assert stats.prefill_tokens < total_prompt
    assert "hit=" in stats.summary()


def test_chunked_prefill_bit_identical(served_cold):
    cfg, params, pipe, mk_reqs, reqs_c = served_cold
    reqs_k = mk_reqs()
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8,
        prefill_chunk=8).serve(reqs_k, deadline=1e9)
    for rc, rk in zip(reqs_c, reqs_k):
        assert list(rc.output) == list(rk.output), rc.rid
    assert stats.prefix_hits == 0            # caching off: chunking alone
    assert stats.prefill_tokens == sum(len(r.prompt) for r in reqs_k)


def test_prefix_plus_chunked_combined(served_cold):
    cfg, params, pipe, mk_reqs, reqs_c = served_cold
    reqs_b = mk_reqs()
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8, prefix_caching=True,
        prefill_chunk=8).serve(reqs_b, deadline=1e9)
    for rc, rb in zip(reqs_c, reqs_b):
        assert list(rc.output) == list(rb.output), rc.rid
    # chunked registration lands later (prompt completes over several
    # iterations), so concurrent riders hit less than one-shot warm serving
    # — but the serialized duplicate and late riders still hit
    assert stats.prefix_hits >= 2


def test_chunked_prefill_fairness_long_prompt_does_not_stall_decode():
    """Iteration-level fairness: with chunking, a short request riding
    behind a giant prompt starts decoding while the giant is still
    prefilling — its first token lands EARLIER on the virtual clock than
    under one-shot prefill (prefill_token_cost makes prefill work visible
    to the clock)."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    def mk():
        rng = np.random.RandomState(5)
        return [Request(rid=0, prompt=rng.randint(0, cfg.vocab_size, 40
                                                  ).astype(np.int32),
                        max_new_tokens=4, arrival=0.0),
                Request(rid=1, prompt=rng.randint(0, cfg.vocab_size, 5
                                                  ).astype(np.int32),
                        max_new_tokens=4, arrival=0.01)]

    kw = dict(n_slots=2, max_len=64, block_size=8, prefill_token_cost=0.125)
    one = mk()
    PagedPipelineBatcher(pipe(), **kw).serve(one, deadline=1e9)
    chunked = mk()
    PagedPipelineBatcher(pipe(), prefill_chunk=8, **kw).serve(chunked,
                                                              deadline=1e9)
    assert list(one[0].output) == list(chunked[0].output)
    assert list(one[1].output) == list(chunked[1].output)
    # the short request's TTFT improves; the giant prompt pays the chunks
    assert chunked[1].first_token_time < one[1].first_token_time


def test_prefix_cache_eviction_under_pool_pressure():
    """A pool too small to keep every cached prefix resident must evict
    LRU prefixes (not crash, not corrupt): distinct prompts streamed
    through a tight pool still decode exactly like cold serving."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe():
        return AsymmetricPipeline(cfg, params, [1, L - 1], [[dev], [dev]])

    def mk():
        rng = np.random.RandomState(11)
        return [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, 18
                                                  ).astype(np.int32),
                        max_new_tokens=4, arrival=1.0 * i)
                for i in range(5)]

    reqs_c = mk()
    PipelineBatcher(pipe(), n_slots=2, max_len=32).serve(reqs_c,
                                                         deadline=1e9)
    reqs_p = mk()
    # 9 usable blocks: one request needs 3; five distinct cached prefixes
    # (2 full blocks each) cannot all stay resident
    stats = PagedPipelineBatcher(
        pipe(), n_slots=2, max_len=32, block_size=8, stage_blocks=[10, 10],
        prefix_caching=True).serve(reqs_p, deadline=1e9)
    for rc, rp in zip(reqs_c, reqs_p):
        assert list(rc.output) == list(rp.output), rc.rid
    assert stats.prefix_hits == 0            # all prompts distinct


def test_shared_prefix_workload_generator():
    reqs = shared_prefix_workload(rate=50.0, duration=0.3, vocab=100,
                                  shared_len=24, unique_len=6, out_len=4,
                                  seed=2)
    assert len(reqs) >= 3
    for r in reqs:
        assert np.array_equal(r.prompt[:24], reqs[0].prompt[:24])
        assert len(r.prompt) >= 30
    # >= 50% of every prompt is the shared system prompt
    assert all(24 / len(r.prompt) >= 0.5 for r in reqs)


def test_hybrid_stack_disables_context_mode_gracefully():
    cfg = get_config("jamba-v0.1-52b").reduced()
    assert not context_mode_supported(cfg)
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    pipe = AsymmetricPipeline(cfg, params, [cfg.num_layers], [[dev]])
    with pytest.warns(UserWarning, match="attention-only"):
        eng = PagedPipelineBatcher(pipe, n_slots=2, max_len=32,
                                   block_size=8, prefix_caching=True,
                                   prefill_chunk=8)
    assert not eng.prefix_caching and eng.prefill_chunk == 0


# ---------------------------------------------------------------------------
# Scheduler: prefix-hit-aware effective KV demand
# ---------------------------------------------------------------------------

def test_concurrent_capacity_prefix_hit_aware():
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    task = cm.Task(batch=1, s_in=512, s_out=64)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    c = cl.case_study_cluster()
    devs = [0, 1, 2, 3]
    base = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16)
    half = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16,
                                  prefix_hit_rate=0.5)
    full = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16,
                                  prefix_hit_rate=1.0)
    assert base < half < full
    # dedup is block-granular: a sub-block hit changes nothing
    tiny = cm.concurrent_capacity(c, devs, 48, prof, task, block_size=16,
                                  prefix_hit_rate=15 / 512)
    assert tiny == base


def test_evaluator_threads_prefix_hit_rate():
    from repro.core import cluster as cl
    from repro.core import cost_model as cm
    from repro.core.genetic import Evaluator
    from repro.core.plan import PipelinePlan, StagePlan
    task = cm.Task(batch=1, s_in=128, s_out=64)
    prof = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                       paper_exact=True)
    c = cl.case_study_cluster()
    plan = PipelinePlan([StagePlan([0, 1, 2, 3], 48), StagePlan([4, 5], 20),
                         StagePlan([6, 7], 12)], cost=1.0, bottleneck=0.2)
    ev = Evaluator(c, prof, task, deadline=3.0, rate=4.0, kv_block_size=16)
    ev_hit = Evaluator(c, prof, task, deadline=3.0, rate=4.0,
                       kv_block_size=16, prefix_hit_rate=0.75)
    assert ev_hit._max_concurrent(plan) > ev._max_concurrent(plan)
