"""Speculative decoding: verification kernel vs oracle, proposer units,
speculative-page rollback refcount safety (BlockTable.truncate + prefix
aliasing), model-level multi-token verification vs sequential decode, and
end-to-end TOKEN-IDENTITY of spec-enabled serving against plain greedy
decode — including under prefix-cache hits, chunked prefill, preemption and
disaggregated decode replicas. The subsystem's correctness bar: speculation
may only change HOW MANY target steps a generation takes, never which
tokens it produces."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # noqa: F401 (skips when absent)

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serving.block_manager import (BlockPool, BlockTable, PrefixIndex,
                                         blocks_for_tokens, chunk_hashes)
from repro.serving.continuous import PagedPipelineBatcher
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request, shared_prefix_workload
from repro.serving.spec import (DraftModelProposer, NgramProposer,
                                SpecConfig, greedy_accept,
                                rejection_sample_accept)

KEY = jax.random.PRNGKey(0)


def rn(i, *shape):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Verification kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_verify_kernel_vs_ref(dtype):
    """The Pallas multi-token verification kernel (interpret mode) against
    the gathered oracle: per-slot KV-start offsets, ragged candidate
    counts, and a dead row (zero candidates)."""
    b, T, hq, hkv, d, bs, nblk = 3, 4, 4, 2, 32, 16, 12
    q = rn(1, b, T, hq, d).astype(dtype)
    kp = rn(2, nblk, bs, hkv, d).astype(dtype)
    vp = rn(3, nblk, bs, hkv, d).astype(dtype)
    bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6], [7, 8, 0, 0]],
                              np.int32))
    kv_start = jnp.array([17, 40, 0])
    kv_len = jnp.array([17 + 4, 40 + 2, 0])      # row 2: dead (no valid KV)
    with ops.backend("pallas_interpret"):
        got = ops.paged_verify_attention(q, kp, vp, bt, kv_start=kv_start,
                                         kv_len=kv_len)
    want = ref.paged_verify_attention_ref(q, kp, vp, bt, kv_start=kv_start,
                                          kv_len=kv_len)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    assert np.all(np.asarray(got, np.float32)[2] == 0.0)   # dead row exact


def test_ops_verify_xla_matches_gathered_oracle():
    b, T, hq, hkv, d, bs, nblk = 2, 3, 4, 2, 16, 8, 10
    q = rn(4, b, T, hq, d)
    kp = rn(5, nblk, bs, hkv, d)
    vp = rn(6, nblk, bs, hkv, d)
    bt = jnp.asarray(np.array([[2, 4, 6, 1], [3, 5, 7, 9]], np.int32))
    kv_start = jnp.array([9, 20])
    kv_len = jnp.array([12, 23])
    got = ops.paged_verify_attention(q, kp, vp, bt, kv_start=kv_start,
                                     kv_len=kv_len)
    want = ref.paged_verify_attention_ref(q, kp, vp, bt, kv_start=kv_start,
                                          kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_verify_single_token_degenerates_to_decode():
    """A one-candidate chunk (the bonus token alone) is exactly a paged
    decode step: same attention output at the same position."""
    b, hq, hkv, d, bs, nblk = 2, 4, 2, 16, 8, 9
    kp = rn(7, nblk, bs, hkv, d)
    vp = rn(8, nblk, bs, hkv, d)
    q = rn(9, b, 1, hq, d)
    bt = jnp.asarray(np.array([[2, 4, 6], [3, 5, 7]], np.int32))
    pos = jnp.array([11, 19])
    got = ref.paged_verify_attention_ref(q, kp, vp, bt, kv_start=pos,
                                         kv_len=pos + 1)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, kv_len=pos + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_verify_step_paged_matches_sequential_decode():
    """Model-level: verifying a chunk of ALREADY-COMMITTED tokens in one
    multi-token step reproduces the logits sequential single-token decode
    produces at each position — the identity greedy acceptance rides on."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    rng = np.random.RandomState(0)
    bs, n_slots, T = 8, 2, 4
    nbmax = 4
    prompt_len = 6
    toks = rng.randint(0, cfg.vocab_size, size=(n_slots, prompt_len)
                       ).astype(np.int32)
    lens = np.full((n_slots,), prompt_len, np.int32)
    # contiguous prefill, scattered into pages (round-robin disjoint tables)
    cache = M.init_cache(cfg, n_slots, nbmax * bs)
    lg, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)}, cache,
                          lens=jnp.asarray(lens))
    bt = (1 + np.arange(n_slots * nbmax, dtype=np.int32)
          ).reshape(n_slots, nbmax)
    pages = {
        k: M.scatter_cache_rows_paged(
            M.init_paged_cache(cfg, 1 + n_slots * nbmax, bs, n_slots)[k],
            cache[k], list(range(n_slots)), bt.reshape(-1), batch_axis=1)
        for k in cache}
    # sequential: decode T tokens one at a time, collecting logits
    chunk = rng.randint(0, cfg.vocab_size, size=(n_slots, T)).astype(np.int32)
    pages_seq = jax.tree.map(lambda x: x, pages)
    seq_logits = []
    pos = lens.copy()
    for t in range(T):
        lg_t, pages_seq = M.decode_step_paged(
            cfg, params, jnp.asarray(chunk[:, t]), pages_seq,
            jnp.asarray(pos), jnp.asarray(bt))
        seq_logits.append(np.asarray(lg_t))
        pos += 1
    # one multi-token verification step over the same chunk
    ver_logits, _ = M.verify_step_paged(
        cfg, params, jnp.asarray(chunk), pages, jnp.asarray(lens),
        jnp.asarray(np.full((n_slots,), T, np.int32)), jnp.asarray(bt))
    ver_logits = np.asarray(ver_logits)
    for t in range(T):
        np.testing.assert_allclose(ver_logits[:, t], seq_logits[t],
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# Acceptance rules
# ---------------------------------------------------------------------------

def test_greedy_accept_commits_matching_prefix():
    V = 8
    logits = np.full((4, V), -1.0, np.float32)
    logits[0, 3] = 1.0       # after bonus: target says 3
    logits[1, 5] = 1.0       # after draft 3: target says 5
    logits[2, 2] = 1.0       # after draft 5: target says 2 (draft said 7)
    commit, a = greedy_accept(logits, bonus=1, drafts=[3, 5, 7])
    assert commit == [1, 3, 5] and a == 2
    # all accepted: commit = bonus + every draft
    commit, a = greedy_accept(logits, bonus=1, drafts=[3, 5])
    assert commit == [1, 3, 5] and a == 2
    # first draft wrong: only the bonus commits
    commit, a = greedy_accept(logits, bonus=1, drafts=[4])
    assert commit == [1] and a == 0
    # no drafts: plain decode
    commit, a = greedy_accept(logits, bonus=6, drafts=[])
    assert commit == [6] and a == 0


def test_rejection_sample_accept():
    V = 4
    pt = np.zeros((3, V)); pt[:, 0] = 1.0           # target is certain of 0
    pd = np.zeros((3, V)); pd[:, 0] = 1.0
    # draft proposes exactly the target's token: always accepted
    commit, a = rejection_sample_accept(pt, pd, [0, 0, 0],
                                        np.array([0.99, 0.99, 0.99]))
    assert commit == [0, 0, 0] and a == 3
    # draft proposes a token the target gives zero mass: rejected at j=0
    # and the resample comes from the residual (= the target itself)
    pd2 = np.zeros((3, V)); pd2[:, 1] = 1.0
    commit, a = rejection_sample_accept(pt, pd2, [1, 1, 1],
                                        np.array([0.999, 0.5, 0.5]))
    assert a == 0 and commit[0] == 0 and len(commit) == 1


# ---------------------------------------------------------------------------
# Proposers
# ---------------------------------------------------------------------------

def test_ngram_proposer_prompt_lookup():
    p = NgramProposer(ngram_max=3, ngram_min=1)
    # history ends with (7, 8), seen earlier followed by 9, 4
    hist = np.array([1, 7, 8, 9, 4, 2, 7, 8], np.int32)
    out = p.propose([(0, hist, 2)])
    assert list(out[0]) == [9, 4]
    # cap respected
    out = p.propose([(0, hist, 1)])
    assert list(out[0]) == [9]
    # the MOST RECENT earlier occurrence wins
    hist2 = np.array([7, 8, 1, 7, 8, 2, 7, 8], np.int32)
    out = p.propose([(0, hist2, 1)])
    assert list(out[0]) == [2]
    # nothing repeats: no proposal (slot absent from the result)
    out = p.propose([(0, np.arange(8, dtype=np.int32), 3)])
    assert 0 not in out
    # zero cap: skipped
    assert p.propose([(0, hist, 0)]) == {}


def test_draft_proposer_matches_draft_greedy_chain():
    """The draft proposer's k proposals are exactly the draft model's own
    greedy continuation of the history, and accepted commits keep its
    cache in sync (no re-prefill on the next round)."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    prop = DraftModelProposer(cfg, params, n_slots=2, max_len=32)
    rng = np.random.RandomState(1)
    hist = rng.randint(0, cfg.vocab_size, size=7).astype(np.int32)
    k = 3
    got = prop.propose([(0, hist, k)])[0]
    # independent greedy reference on the same model
    cache = M.init_cache(cfg, 1, 32)
    lg, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(hist[None])},
                          cache, lens=jnp.asarray([len(hist)]))
    # prefill consumed the full history; its logits predict the token
    # AFTER hist[-1], which is the first proposal
    want = []
    pos = len(hist)
    for _ in range(k):
        nxt = int(np.asarray(lg)[0].argmax())
        want.append(nxt)
        lg, cache = M.decode_step(cfg, params, jnp.asarray([nxt]), cache,
                                  jnp.asarray([pos]))
        pos += 1
    assert list(got) == want
    # accept ALL 3 (the full-acceptance path: the extra write-only step
    # must have cached the final proposal's K/V): the next round syncs
    # without re-prefilling and still matches the reference chain
    steps_before = prop.draft_steps
    prop.commit(0, k)
    bonus2 = int(np.asarray(lg)[0].argmax())         # token after want[-1]
    hist2 = np.concatenate([hist, np.asarray(want, np.int32),
                            np.asarray([bonus2], np.int32)])
    got2 = prop.propose([(0, hist2, k)])
    # k proposal steps + 1 write-only step, no re-prefill
    assert prop.draft_steps == steps_before + k + 1
    want2 = []
    pos2 = len(hist2) - 1
    lg2, cache2 = M.decode_step(cfg, params, jnp.asarray([bonus2]), cache,
                                jnp.asarray([pos2]))
    for _ in range(k):
        nxt = int(np.asarray(lg2)[0].argmax())
        want2.append(nxt)
        lg2, cache2 = M.decode_step(cfg, params, jnp.asarray([nxt]),
                                    cache2, jnp.asarray([pos2 + 1]))
        pos2 += 1
    assert list(got2[0]) == want2
    # release forgets the slot: next propose re-prefills from scratch
    prop.release(0)
    assert prop._pos[0] == 0


# ---------------------------------------------------------------------------
# Speculative-page rollback: BlockTable.truncate
# ---------------------------------------------------------------------------

def test_truncate_frees_trailing_blocks():
    pool = BlockPool(8, block_size=4)
    t = BlockTable(pool)
    assert t.allocate_tokens(20)             # 5 blocks
    assert t.n_blocks == 5 and pool.n_free == 2
    assert t.truncate(9) == 2                # keep 3 blocks (9 tokens)
    assert t.n_blocks == 3 and pool.n_free == 4
    assert t.truncate(9) == 0                # idempotent
    assert t.truncate(0) == 3
    assert pool.n_free == 7 and t.n_blocks == 0


def test_truncate_shared_block_keeps_other_references():
    """Rolling back a speculative tail that aliases an index-registered
    block must not free it out from under the index (prefix-index-safe)."""
    pool = BlockPool(8, block_size=4)
    ix = PrefixIndex(pool)
    t = BlockTable(pool)
    assert t.allocate_tokens(12)             # 3 blocks
    toks = list(range(8))                    # 2 full chunks
    hashes = chunk_hashes(toks, 4)
    ix.register(hashes, t.blocks[:2])        # index holds blocks 0..1
    shared = t.blocks[1]
    assert pool.ref(shared) == 2
    t.truncate(4)                            # drop blocks 1 and 2
    assert pool.ref(shared) == 1             # the index's reference lives
    assert ix.n_evictable() >= 1
    # a later prompt can still alias the registered prefix
    t2 = BlockTable(pool)
    t2.adopt(ix.acquire(hashes[:2]))
    assert t2.blocks[1] == shared and pool.ref(shared) == 2
    t.release()
    t2.release()
    ix.clear()
    assert pool.n_free == pool.n_blocks - 1  # nothing stranded


@settings(max_examples=40, deadline=None)
@given(st.integers(6, 16), st.integers(1, 4), st.integers(0, 10 ** 6))
def test_truncate_adopt_refcount_property(n_usable, block_size, seed):
    """Property (the rollback/adopt interaction): random interleavings of
    prefix registration, prefix adoption, speculative growth and
    truncate-rollback keep every block's refcount equal to an independent
    holder model's count — and releasing everything drains the pool
    completely (no stranded refcounts, no double frees)."""
    rng = np.random.RandomState(seed)
    pool = BlockPool(n_usable + 1, block_size)
    ix = PrefixIndex(pool)
    prompt = list(range(3 * block_size))     # 3 registrable chunks
    hashes = chunk_hashes(prompt, block_size)
    tables = [BlockTable(pool) for _ in range(3)]
    committed = [0] * 3                      # committed tokens per table

    for _ in range(30):
        i = rng.randint(3)
        t = tables[i]
        op = rng.choice(["adopt", "grow", "truncate", "register",
                         "release"])
        if op == "adopt" and not t.blocks:
            L = ix.match_len(hashes)
            if L:
                t.adopt(ix.acquire(hashes[:L]))
                committed[i] = L * block_size
        elif op == "grow":
            # speculative chunk: may fail when the pool is dry — that is
            # the engine's preempt path, not an invariant violation
            want = committed[i] + rng.randint(1, 2 * block_size + 1)
            if t.allocate_tokens(want):
                committed[i] = want if rng.rand() < 0.5 else committed[i]
        elif op == "truncate":
            # rollback to the committed length (or a random earlier point)
            back = rng.randint(0, committed[i] + 1)
            t.truncate(back)
            committed[i] = min(committed[i], back)
        elif op == "register" and t.n_blocks >= 1:
            n_full = min(t.n_blocks, len(hashes))
            ix.register(hashes[:n_full], t.blocks[:n_full])
        elif op == "release":
            t.release()
            committed[i] = 0
        # refcount == table holders + index holder, every block
        for bid in range(1, pool.n_blocks):
            want = sum(b == bid for tt in tables for b in tt.blocks) \
                + (1 if bid in ix._hash_of else 0)
            assert pool.ref(bid) == want, (bid, op)
    for t in tables:
        t.release()
    ix.clear()
    assert pool.n_free == pool.n_blocks - 1


# ---------------------------------------------------------------------------
# End-to-end: spec serving == plain greedy serving, token for token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    dev = jax.devices()[0]
    L = cfg.num_layers

    def pipe(split=None):
        split = split if split is not None else [1, L - 1]
        return AsymmetricPipeline(cfg, params, split, [[dev]] * len(split))

    return cfg, params, pipe


def _mk_reqs(cfg, *, n=4, max_new=8, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=5 + 3 * i).astype(np.int32),
                    max_new_tokens=max_new, arrival=0.02 * i)
            for i in range(n)]


@pytest.fixture(scope="module")
def served_baseline(setup):
    cfg, params, pipe = setup
    reqs = _mk_reqs(cfg)
    PagedPipelineBatcher(pipe(), n_slots=3, max_len=48,
                         block_size=8).serve(reqs, deadline=1e9)
    return reqs


def test_spec_ngram_token_identical(setup, served_baseline):
    cfg, params, pipe = setup
    reqs = _mk_reqs(cfg)
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8,
        spec=SpecConfig(k=3, proposer="ngram")).serve(reqs, deadline=1e9)
    assert stats.spec_steps > 0
    assert stats.spec_tokens == sum(len(r.output) for r in reqs)
    for rc, rs in zip(served_baseline, reqs):
        assert list(rc.output) == list(rs.output), rc.rid


def test_spec_self_draft_identical_and_fewer_steps(setup, served_baseline):
    """Draft == target: acceptance is near-total (up to argmax ties
    between the monolithic draft path and the pipeline verify path), so
    each target step commits well over one token."""
    cfg, params, pipe = setup
    reqs = _mk_reqs(cfg)
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8,
        spec=SpecConfig(k=3, proposer="draft", draft_cfg=cfg,
                        draft_params=params)).serve(reqs, deadline=1e9)
    for rc, rs in zip(served_baseline, reqs):
        assert list(rc.output) == list(rs.output), rc.rid
    assert stats.spec_tokens / stats.spec_steps > 1.5, \
        (stats.spec_tokens, stats.spec_steps)


def test_spec_with_prefix_cache_and_chunked_prefill(setup):
    cfg, params, pipe = setup

    def wl():
        return shared_prefix_workload(rate=4.0, duration=1.5,
                                      vocab=cfg.vocab_size, shared_len=24,
                                      unique_len=6, out_len=6, seed=3)

    cold = wl()
    PagedPipelineBatcher(pipe(), n_slots=4, max_len=48,
                         block_size=8).serve(cold, deadline=1e9)
    warm = wl()
    stats = PagedPipelineBatcher(
        pipe(), n_slots=4, max_len=48, block_size=8, prefix_caching=True,
        prefill_chunk=16, spec=SpecConfig(k=3)).serve(warm, deadline=1e9)
    assert stats.prefix_hits > 0 and stats.spec_steps > 0
    for rc, rw in zip(cold, warm):
        assert list(rc.output) == list(rw.output), rc.rid


def test_spec_preemption_recomputes_identically(setup):
    """A dry pool mid-speculation preempts by recompute, and the requeued
    request still finishes with exactly the baseline tokens — rollback,
    release and draft-state reset compose."""
    cfg, params, pipe = setup

    def reqs(seed=1):
        rng = np.random.RandomState(seed)
        return [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           size=6).astype(np.int32),
                        max_new_tokens=20, arrival=0.0) for i in range(3)]

    rc = reqs()
    PagedPipelineBatcher(pipe(), n_slots=3, max_len=32,
                         block_size=8).serve(rc, deadline=1e9)
    rs = reqs()
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=32, block_size=8, stage_blocks=[9, 9],
        admit_headroom=2, spec=SpecConfig(k=3)).serve(rs, deadline=1e9)
    assert stats.preemptions > 0
    for a, b in zip(rc, rs):
        assert list(a.output) == list(b.output), a.rid


def test_spec_on_disaggregated_decode_replica(setup, served_baseline):
    """Speculation composes with the prefill/decode split: migrated slots
    seed the verify loop from the migrated logits bit-identically."""
    from repro.serving.disagg import wire_disaggregation
    from repro.serving.loop import VirtualClock, run_serve_loop
    cfg, params, pipe = setup
    reqs = _mk_reqs(cfg)
    workers = [
        PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             role="prefill", spec=SpecConfig(k=3)),
        PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                             role="decode", spec=SpecConfig(k=3)),
    ]
    wire_disaggregation(workers, ["prefill", "decode"])
    stats = run_serve_loop(workers, reqs, deadline=1e9,
                           clock=VirtualClock())
    assert stats.migrations == len(reqs) and stats.spec_steps > 0
    for rc, rs in zip(served_baseline, reqs):
        assert list(rc.output) == list(rs.output), rc.rid


def test_spec_counters_and_bounds(setup):
    """Per-step commits stay within [1, k + 1]; accepted <= proposed;
    committed spec tokens equal the served output tokens."""
    cfg, params, pipe = setup
    reqs = _mk_reqs(cfg, n=3, max_new=10, seed=7)
    k = 3
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8,
        spec=SpecConfig(k=k, proposer="draft", draft_cfg=cfg,
                        draft_params=params)).serve(reqs, deadline=1e9)
    total_out = sum(len(r.output) for r in reqs)
    assert stats.spec_tokens == total_out
    assert stats.spec_accepted <= stats.spec_proposed
    assert stats.spec_steps <= total_out                 # never worse
    assert stats.spec_tokens <= stats.spec_steps * (k + 1)


def test_spec_gating_warns_on_hybrid_and_contiguous():
    cfg_h = get_config("jamba-v0.1-52b").reduced()
    params_h = M.init_params(cfg_h, KEY)
    dev = jax.devices()[0]
    ph = AsymmetricPipeline(cfg_h, params_h, [1, cfg_h.num_layers - 1],
                            [[dev], [dev]])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = PagedPipelineBatcher(ph, n_slots=2, max_len=32, block_size=8,
                                   spec=SpecConfig(k=2))
    assert eng.spec is None
    assert any("attention-only" in str(x.message) for x in w)
    # router-level gating: contiguous layout cannot verify through pages
    from repro.serving.router import Router
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    pipe = AsymmetricPipeline(cfg, params, [cfg.num_layers], [[dev]])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = Router([pipe], cache_layout="contiguous",
                   spec=SpecConfig(k=2))
    assert any("paged" in str(x.message) for x in w)


def test_engine_unsuitable_draft_falls_back_to_ngram():
    """A draft config the verification contract cannot support (recurrent
    state, or a mismatched vocab) must not crash serving from a CLI flag:
    the engine warns and speculates with the weight-free proposer."""
    from repro.core.plan import Assignment, PipelinePlan, StagePlan
    from repro.serving.engine import InferenceEngine
    from repro.serving.spec import NgramProposer
    cfg = get_config("granite-8b").reduced()
    asg = Assignment([PipelinePlan([StagePlan([0], cfg.num_layers)],
                                   cost=0.1, bottleneck=0.1)])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = InferenceEngine(
            cfg, asg, key=KEY, policy="continuous", n_slots=2, max_len=32,
            cache_layout="paged", block_size=8, spec_decode=True, spec_k=2,
            draft_model="h2o-danube-1.8b")     # SWA stack: no rollback
    assert any("n-gram" in str(x.message) for x in w)
    worker = eng.router.workers[0]
    assert worker.spec is not None
    assert isinstance(worker._proposer, NgramProposer)


def test_spec_virtual_clock_charges_draft_cost(setup):
    """draft_token_cost > 0 makes proposals visible to the virtual clock:
    the same workload finishes later than with free proposals."""
    from repro.serving.loop import VirtualClock
    cfg, params, pipe = setup
    free = _mk_reqs(cfg, n=2, max_new=6, seed=9)
    PagedPipelineBatcher(
        pipe(), n_slots=2, max_len=48, block_size=8,
        spec=SpecConfig(k=3)).serve(free, deadline=1e9)
    costly = _mk_reqs(cfg, n=2, max_new=6, seed=9)
    PagedPipelineBatcher(
        pipe(), n_slots=2, max_len=48, block_size=8,
        spec=SpecConfig(k=3, draft_token_cost=0.5)).serve(
            costly, deadline=1e9)
    assert max(r.finish_time for r in costly) \
        > max(r.finish_time for r in free)
    for a, b in zip(free, costly):
        assert list(a.output) == list(b.output)      # cost, not content


# ---------------------------------------------------------------------------
# Quantized pages under verification (int8 pools + spec rollback)
# ---------------------------------------------------------------------------

def test_verify_kernel_dead_row_exact_zero_under_int8_pages():
    """The verify kernel's dead-row contract (kv_len == kv_start == 0 ->
    exact zeros) must survive quantized pools: a free slot riding the
    joint dispatch scatters into the null page and its masked row may
    never leak dequantized garbage."""
    from repro.kernels.paged_attention import (
        paged_verify_attention_quant_pallas)
    from repro.models import quant as Q

    b, T, hq, hkv, d, bs, nblk = 3, 4, 4, 2, 32, 16, 12
    q = rn(31, b, T, hq, d)
    kp = rn(32, nblk, bs, hkv, d)
    vp = rn(33, nblk, bs, hkv, d)
    kq, ks = Q.quantize_kv_rows(kp, "int8")
    vq, vs = Q.quantize_kv_rows(vp, "int8")
    bt = jnp.asarray(np.array([[3, 1, 4, 0], [5, 9, 2, 6], [0, 0, 0, 0]],
                              np.int32))
    kv_start = jnp.array([17, 40, 0])
    kv_len = jnp.array([17 + 4, 40 + 2, 0])      # row 2: dead (free slot)
    got = paged_verify_attention_quant_pallas(
        q, kq, vq, ks, vs, bt, kv_start=kv_start, kv_len=kv_len,
        interpret=True)
    want = ref.paged_verify_attention_quant_ref(
        q, kq, vq, ks, vs, bt, kv_start=kv_start, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    assert np.all(np.asarray(got)[2] == 0.0)     # dead row exact
    assert np.all(np.asarray(want)[2] == 0.0)
    # the XLA dispatch honors the same contract with scale operands
    got_x = ops.paged_verify_attention(q, kq, vq, bt, kv_start=kv_start,
                                       kv_len=kv_len, k_scale=ks, v_scale=vs)
    assert np.all(np.asarray(got_x)[2] == 0.0)


def test_spec_serving_int8_pool_token_identical(setup):
    """Speculation over int8 pages: rejected candidates' quantized page
    writes (payload AND scales) sit past the committed length after
    BlockTable.truncate, masked by kv_len and overwritten by the next
    chunk — so spec+int8 must reproduce plain int8 greedy decode token
    for token. (int8 vs fp32 is a STATISTICAL match — quantization may
    legitimately flip a near-tie argmax — and is measured by
    benchmarks/bench_quant_kv.py, not asserted here.)"""
    cfg, params, pipe = setup
    reqs_q = _mk_reqs(cfg)
    PagedPipelineBatcher(pipe(), n_slots=3, max_len=48, block_size=8,
                         kv_dtype="int8").serve(reqs_q, deadline=1e9)
    reqs_s = _mk_reqs(cfg)
    stats = PagedPipelineBatcher(
        pipe(), n_slots=3, max_len=48, block_size=8, kv_dtype="int8",
        spec=SpecConfig(k=3, proposer="ngram")).serve(reqs_s, deadline=1e9)
    assert stats.spec_steps > 0
    assert stats.kv_bytes_saved > 0
    for rq, rs in zip(reqs_q, reqs_s):
        assert list(rq.output) == list(rs.output), rq.rid
