"""Online rescheduling: drift detection, Helix-style max-flow repair,
warm re-solve, and the serving-side chaos executor. The detector/flow/
repair units are pure and fast; the scheduler tests re-solve real
hetero pools; the engine tests kill a replica mid-request and require
the survivors to regenerate IDENTICAL token streams under KVSAN."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cluster as cl
from repro.core import cost_model as cm
from repro.core import genetic, slo_sim
from repro.core.plan import Assignment, DeploymentPlan, PipelinePlan, \
    StagePlan
from repro.core.resched import (DriftDetector, colocated_serve_rate,
                                drop_devices, flow_role_split,
                                flow_serve_rate, max_flow, repair_plan,
                                warm_resolve, warm_seed)
from repro.core.slo_sim import PhasedReplicaModel
from repro.serving.engine import InferenceEngine
from repro.serving.loop import VirtualClock
from repro.serving.request import synth_workload
from repro.serving.resched import OnlineRescheduler

LLAMA = None  # lazily built: the paper profile is only for the slow tests


# ---------------------------------------------------------------------------
# DriftDetector
# ---------------------------------------------------------------------------

def _feed(det, n, dt, plen=0, t0=0.0):
    t = t0
    for _ in range(n):
        det.observe_admit(t, plen)
        t += dt
    return t - dt


def test_rate_spike_fires_and_reanchors():
    det = DriftDetector(rate=1.0)
    t = _feed(det, 10, 0.1)                  # ~10 req/s vs planned 1.0
    sig = det.poll(t)
    assert sig is not None and sig.kind == "rate_spike"
    assert sig.factor >= det.rate_threshold
    assert sig.observed_rate == pytest.approx(det.planned_rate)
    # re-anchored: the same sustained rate does not re-fire
    assert det.poll(t) is None


def test_rate_drop_fires():
    det = DriftDetector(rate=10.0, window=20.0)
    t = _feed(det, 8, 1.0)                   # ~1.1 req/s vs planned 10
    sig = det.poll(t)
    assert sig is not None and sig.kind == "rate_spike"
    assert sig.factor <= 1.0 / det.rate_threshold


def test_needs_min_events():
    det = DriftDetector(rate=1.0, min_events=8)
    t = _feed(det, 7, 0.1)                   # one admit short of the floor
    assert det.poll(t) is None


def test_mix_shift_fires_on_prompt_len_only():
    det = DriftDetector(rate=1.0, prompt_len=100.0, window=20.0)
    t = _feed(det, 8, 1.0, plen=250)         # rate on-plan, prompts 2.5x
    sig = det.poll(t)
    assert sig is not None and sig.kind == "mix_shift"
    assert sig.factor == pytest.approx(2.5)
    assert sig.observed_prompt_len == pytest.approx(250.0)
    t = _feed(det, 8, 1.0, plen=250, t0=t + 1.0)
    assert det.poll(t) is None               # re-anchored at 250


def test_mix_detection_off_without_baseline():
    det = DriftDetector(rate=1.0, window=20.0)   # prompt_len=0 disables
    t = _feed(det, 8, 1.0, plen=4096)
    assert det.poll(t) is None


def test_death_preempts_statistics():
    det = DriftDetector(rate=1.0)
    t = _feed(det, 10, 0.1)                  # a rate spike is also pending
    det.observe_death(frozenset({4, 5}))
    sig = det.poll(t)
    assert sig.kind == "replica_death" and sig.dead == (frozenset({4, 5}),)
    sig2 = det.poll(t)                       # then the spike surfaces
    assert sig2 is not None and sig2.kind == "rate_spike"


def test_acceptance_drift():
    det = DriftDetector(rate=1.0, spec_alpha=0.8, min_events=4,
                        window=20.0)
    t = _feed(det, 4, 1.0)                   # on-plan rate, above the floor
    det.observe_spec(proposed=10, accepted=2)
    sig = det.poll(t)
    assert sig is not None and sig.kind == "acceptance_drift"
    assert sig.observed_alpha == pytest.approx(0.2)
    assert det.planned_alpha == pytest.approx(0.2)   # re-anchored
    det.observe_spec(proposed=10, accepted=2)
    assert det.poll(t) is None


def test_window_trims_old_admits():
    det = DriftDetector(rate=1.0, window=5.0)
    _feed(det, 20, 0.1)                      # burst at t ~ [0, 2)
    assert det.window_rate(100.0) == 0.0     # long quiet: window empty
    assert det.poll(100.0) is None


# ---------------------------------------------------------------------------
# Max-flow over the phase-rate graph
# ---------------------------------------------------------------------------

def test_max_flow_known_graph():
    # s=0, a=1, b=2, t=3:  s->a 3, s->b 2, a->t 2, b->t 3, a->b 1
    cap = np.zeros((4, 4))
    cap[0, 1], cap[0, 2] = 3, 2
    cap[1, 3], cap[2, 3] = 2, 3
    cap[1, 2] = 1
    assert max_flow(cap, 0, 3) == pytest.approx(5.0)


def test_max_flow_disconnected_is_zero():
    assert max_flow(np.zeros((3, 3)), 0, 2) == 0.0


def test_flow_serve_rate_bottleneck():
    assert flow_serve_rate([2.0], [3.0]) == pytest.approx(2.0)
    assert flow_serve_rate([2.0, 2.0], [1.0, 1.0]) == pytest.approx(2.0)
    assert flow_serve_rate([], [1.0]) == 0.0


def test_flow_serve_rate_link_capped():
    link = np.array([[1.5]])
    assert flow_serve_rate([5.0], [5.0], link) == pytest.approx(1.5)


def _phased(pre, dec):
    return PhasedReplicaModel(prefill_latency=pre, prefill_bottleneck=pre,
                              decode_latency=dec, decode_bottleneck=dec)


def test_role_split_complementary_pair():
    # A prefills 10x faster, B decodes 10x faster: the split pushes the
    # flow to 10 req/s where colocation manages ~1.8
    a, b = _phased(0.1, 1.0), _phased(1.0, 0.1)
    roles, rate = flow_role_split([a, b])
    assert roles == ["prefill", "decode"]
    assert rate == pytest.approx(10.0)
    assert rate > colocated_serve_rate([a, b])


def test_role_split_identical_pair_stays_colocated():
    # two identical replicas: any split halves the graph (1.0) while
    # colocation also reaches 1.0 — ties keep the token-safe layout
    a = _phased(1.0, 1.0)
    roles, rate = flow_role_split([a, a])
    assert roles is None
    assert rate == pytest.approx(colocated_serve_rate([a, a]))


def test_role_split_single_replica_colocated():
    roles, rate = flow_role_split([_phased(0.1, 1.0)])
    assert roles is None and rate > 0.0


def test_role_split_prices_the_wire():
    # an infinitely slow handoff wire makes every split worthless
    a, b = _phased(0.1, 1.0), _phased(1.0, 0.1)
    roles, rate = flow_role_split([a, b], kv_bytes=1e12, link_bw=1.0)
    assert roles is None
    assert rate == pytest.approx(colocated_serve_rate([a, b]))


# ---------------------------------------------------------------------------
# repair_plan / drop_devices / warm_seed
# ---------------------------------------------------------------------------

def _plan(groups, roles=None):
    asg = Assignment([PipelinePlan([StagePlan(list(g), 4)], cost=0.1,
                                   bottleneck=0.1) for g in groups])
    return DeploymentPlan.from_search(asg, roles=roles)


def test_repair_drops_dead_and_colocates():
    plan = _plan([[0, 1], [2, 3], [4, 5]],
                 roles=["prefill", "decode", "decode"])
    out = repair_plan(plan, [frozenset({2, 3})])
    assert {tuple(sorted(r.key)) for r in out.replicas} == \
        {(0, 1), (4, 5)}
    # no models given: every survivor falls back to end-to-end serving
    assert [r.role for r in out.replicas] == ["both", "both"]
    assert out.dims == plan.dims


def test_repair_resplits_by_flow():
    plan = _plan([[0], [1], [2]], roles=["prefill", "prefill", "decode"])
    out = repair_plan(plan, [frozenset({2})],
                      models=[_phased(0.1, 1.0), _phased(1.0, 0.1)])
    assert [r.role for r in out.replicas] == ["prefill", "decode"]


def test_repair_without_roles_dim_keeps_specs():
    plan = _plan([[0, 1], [2, 3]])           # dims == frozenset()
    out = repair_plan(plan, [frozenset({0, 1})])
    assert len(out.replicas) == 1 and out.replicas[0].role == "both"
    assert out.dims == frozenset()


def test_drop_devices_renumbers_contiguously():
    pool = cl.case_study_cluster()
    n = len(pool.devices)
    pool2, remap = drop_devices(pool, [0, 3])
    assert len(pool2.devices) == n - 2
    assert [d.id for d in pool2.devices] == list(range(n - 2))
    assert sorted(remap) == [d for d in range(n) if d not in (0, 3)]
    assert sorted(remap.values()) == list(range(n - 2))
    assert pool2.lat.shape == pool2.bw.shape == (n - 2, n - 2)
    # surviving pairwise bandwidth is preserved under the renumbering
    old, new = sorted(remap)[:2], [remap[k] for k in sorted(remap)[:2]]
    assert pool2.bw[new[0], new[1]] == pool.bw[old[0], old[1]]


def test_warm_seed_projects_and_pools_the_rest():
    plan = _plan([[0, 1], [2, 3]])
    remap = {0: 0, 1: 1, 3: 2}               # device 2 died; pool grew to 5
    seed = warm_seed(plan, remap, pool_size=5)
    assert seed == (frozenset({0, 1}), frozenset({2}), frozenset({3, 4}))


def test_warm_seed_drops_fully_dead_replicas():
    plan = _plan([[0, 1], [2, 3]])
    seed = warm_seed(plan, {0: 0, 1: 1}, pool_size=2)
    assert seed == (frozenset({0, 1}),)


# ---------------------------------------------------------------------------
# Warm re-solve on the paper pool (scheduler-level)
# ---------------------------------------------------------------------------

def _llama():
    global LLAMA
    if LLAMA is None:
        LLAMA = cm.ModelProfile.from_config(get_config("llama2-70b"),
                                            paper_exact=True)
    return LLAMA


def _replica_models(pool, asg, prof, task):
    out = []
    for pipe in asg.pipelines:
        pc = cm.pipeline_phase_costs(
            pool, [s.device_ids for s in pipe.stages], pipe.layer_split,
            prof, task)
        out.append(PhasedReplicaModel(
            prefill_latency=pc.prefill_latency,
            prefill_bottleneck=pc.prefill_bottleneck,
            decode_latency=pc.decode_latency,
            decode_bottleneck=pc.decode_bottleneck).colocated())
    return out


@pytest.mark.slow
def test_warm_resolve_excludes_dead_devices():
    pool = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    res = genetic.search(pool, _llama(), task, deadline=10.0, rate=3.0,
                         iters=6, seed=0)
    dead = list(range(4))
    res2, remap = warm_resolve(pool, _llama(), task, incumbent=res.plan,
                               deadline=10.0, rate=3.0, dead_devices=dead,
                               iters=4, seed=1)
    assert res2.attainment > 0.0
    assert set(remap) == {d.id for d in pool.devices} - set(dead)
    used = {d for p in res2.assignment.pipelines for d in p.device_ids}
    assert used <= set(range(len(pool.devices) - len(dead)))
    res2.plan.validate(_llama().num_layers)


@pytest.mark.slow
def test_spike_resolve_strictly_improves_attainment():
    """The ISSUE's chaos contract at the bench's operating point: an
    incumbent solved for 1.5 req/s with SLO headroom, hit by a sustained
    spike — re-solving AT the observed rate must strictly beat the
    incumbent's simulated attainment under that rate."""
    pool = cl.hetero_half_price()
    task = cm.Task(batch=1, s_in=128, s_out=32)
    deadline, obs = 30.0, 6.0
    res = genetic.search(pool, _llama(), task, deadline=deadline,
                         rate=1.5, iters=15, seed=0)
    att_inc = slo_sim.simulate(
        _replica_models(pool, res.assignment, _llama(), task), obs,
        deadline)
    res2, _ = warm_resolve(pool, _llama(), task, incumbent=res.plan,
                           deadline=deadline, rate=obs, iters=8, seed=1)
    att_new = slo_sim.simulate(
        _replica_models(pool, res2.assignment, _llama(), task), obs,
        deadline)
    assert att_new > att_inc, (att_new, att_inc)
    assert res2.assignment.num_replicas >= res.assignment.num_replicas


# ---------------------------------------------------------------------------
# Engine-level chaos: replica kill is token-invisible
# ---------------------------------------------------------------------------

BLOCK = 8


@pytest.fixture(scope="module")
def chaos_setup():
    cfg = get_config("granite-8b").reduced()
    L = cfg.num_layers
    asg = Assignment([
        PipelinePlan([StagePlan([0], 1), StagePlan([1], L - 1)],
                     cost=0.1, bottleneck=0.1),
        PipelinePlan([StagePlan([2], L - 1), StagePlan([3], 1)],
                     cost=0.1, bottleneck=0.1),
    ])

    def wl():
        return synth_workload(rate=10.0, duration=1.0, vocab=cfg.vocab_size,
                              prompt_len=10, prompt_jitter=5, out_len=4,
                              seed=2)

    def engine():
        return InferenceEngine(cfg, asg, key=jax.random.PRNGKey(0),
                               policy="continuous", n_slots=4, max_len=48,
                               cache_layout="paged", block_size=BLOCK,
                               kvsan=True)

    cold = wl()
    stats = engine().serve(cold, deadline=1e9, clock=VirtualClock())
    assert stats.dropped == 0 and stats.kvsan_leaks == 0
    return wl, engine, [list(r.output) for r in cold]


def _kill_run(chaos_setup, t_kill):
    wl, engine, cold = chaos_setup
    reqs = wl()
    eng = engine()
    ctl = OnlineRescheduler(kills=[(t_kill, 1)])
    eng.router.attach_controller(ctl)
    stats = eng.serve(reqs, deadline=1e9, clock=VirtualClock())
    assert stats.dropped == 0, stats.summary()
    assert stats.kvsan_leaks == 0, stats.summary()
    kills = [e for e in ctl.events if e["kind"] == "kill"]
    assert kills, ctl.events
    for want, req in zip(cold, reqs):
        assert want == list(req.output), (req.rid, want, list(req.output))
    return ctl, kills[0]


def test_replica_kill_mid_prefill_token_identical(chaos_setup):
    # t=0.2: replica 1 dies while its first admissions are still
    # prefilling — the re-dispatch is a cold re-prefill on the survivor
    ctl, kill = _kill_run(chaos_setup, 0.2)
    assert ctl.redispatches == kill["orphans"] >= 0


def test_replica_kill_mid_decode_token_identical(chaos_setup):
    # t=2.0: replica 1 dies holding decoding slots with emitted tokens —
    # survivors must REgenerate them identically from the prompts
    ctl, kill = _kill_run(chaos_setup, 2.0)
    assert kill["orphans"] > 0
    assert ctl.redispatches > 0
