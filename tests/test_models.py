"""Per-arch smoke tests (REDUCED variants): one forward/train step on CPU
asserting shapes + no NaNs, plus prefill/decode consistency with the full
forward. The full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.training import optimizer
from repro.training.train_step import make_train_step

ASSIGNED = ["granite-8b", "jamba-v0.1-52b", "h2o-danube-1.8b",
            "granite-moe-3b-a800m", "granite-20b", "xlstm-125m",
            "paligemma-3b", "codeqwen1.5-7b", "phi3.5-moe-42b-a6.6b",
            "whisper-base"]

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b, s):
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(KEY, 1), (b, s), 0, cfg.vocab_size)}
    if cfg.num_image_tokens:
        batch["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 2),
            (b, cfg.num_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 3), (b, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    b, s = 2, 16
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, b, s)

    logits, aux = M.train_forward(cfg, params, batch)
    total = s + cfg.num_image_tokens
    assert logits.shape == (b, total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    opt_cfg = optimizer.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = optimizer.init(params)
    step = make_train_step(cfg, opt_cfg)
    new_params, new_state, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    assert int(new_state.step) == 1
    # params actually changed
    moved = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b_: (a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)),
                     new_params, params), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_match_forward(arch):
    cfg = get_config(arch).reduced()
    b, s, new = 2, 12, 3
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, b, s)

    cache = M.init_cache(cfg, b, s + new + cfg.num_image_tokens)
    lg, cache = M.prefill(cfg, params, batch, cache)
    logits, _ = M.train_forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               atol=2e-4)

    toks = batch["tokens"]
    pos = s + cfg.num_image_tokens
    for _ in range(new):
        nxt = jnp.argmax(lg, -1)
        lg, cache = M.decode_step(cfg, params, nxt, cache, pos)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
        pos += 1
    logits_ext, _ = M.train_forward(cfg, params, dict(batch, tokens=toks))
    nxt = jnp.argmax(logits_ext[:, -1], -1)
    # final decode logits match the full forward on the extended sequence
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(logits_ext[:, -1], np.float32),
                               atol=2e-3)


def test_left_padding_equivalence():
    """A left-padded shorter prompt decodes like the unpadded one."""
    cfg = get_config("granite-8b").reduced()
    params = M.init_params(cfg, KEY)
    s, pad = 10, 4
    toks = jax.random.randint(jax.random.fold_in(KEY, 9), (1, s), 0,
                              cfg.vocab_size)
    # unpadded
    c1 = M.init_cache(cfg, 1, s + 2)
    lg1, _ = M.prefill(cfg, params, {"tokens": toks}, c1)
    # left-padded
    padded = jnp.concatenate(
        [jnp.zeros((1, pad), toks.dtype), toks], axis=1)
    c2 = M.init_cache(cfg, 1, s + pad + 2)
    lg2, _ = M.prefill(cfg, params, {"tokens": padded}, c2,
                       kv_start=jnp.array([pad]))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=2e-4)


def test_swa_ring_cache_decode():
    """Decode with a ring cache (window smaller than history) matches a
    full-cache decode restricted to the window."""
    import dataclasses
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                              swa_window=8)
    params = M.init_params(cfg, KEY)
    b, s = 1, 12
    toks = jax.random.randint(jax.random.fold_in(KEY, 4), (b, s), 0,
                              cfg.vocab_size)
    cache = M.init_cache(cfg, b, s + 4)     # ring size = window = 8
    lg, cache = M.prefill(cfg, params, {"tokens": toks}, cache)
    for k in range(3):
        nxt = jnp.argmax(lg, -1)
        lg, cache = M.decode_step(cfg, params, nxt, cache, s + k)
        assert bool(jnp.isfinite(lg).all())
    # reference: full attention with window mask via train_forward
    # (cfg.swa_window applies inside flash attention for the full pass too)


def test_moe_aux_loss_positive():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 16)
    _, aux = M.train_forward(cfg, params, batch)
    assert float(aux) >= 0.0


def test_loss_decreases_training():
    """~100 steps on the Markov stream: loss must drop measurably."""
    from repro.training.data import DataConfig, SyntheticStream
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    opt_cfg = optimizer.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      batch_size=4, seed=0))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, losses[::10]
