"""Optional-hypothesis shim: property tests collect-and-skip on a bare
environment instead of breaking collection for the whole suite."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def settings(**kw):           # decoration-time stand-ins so modules
        return lambda f: f        # collect; the tests themselves skip

    def given(*a, **kw):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = f.__name__
            return _skipped
        return deco

    class st:                     # only what @given lines evaluate eagerly
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def floats(*a, **kw):
            return None

        @staticmethod
        def data():
            return None
