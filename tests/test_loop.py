"""Unified serving loop: continuous batching on multi-stage asymmetric
pipelines must be bit-identical to isolated generation, the virtual clock
must make whole served workloads deterministic, and the analytic SLO
simulator must share the loop's admission semantics."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import slo_sim
from repro.models import model as M
from repro.serving.continuous import PipelineBatcher
from repro.serving.loop import VirtualClock, run_serve_loop
from repro.serving.pipeline import AsymmetricPipeline
from repro.serving.request import Request, synth_workload
from repro.serving.router import Router

KEY = jax.random.PRNGKey(0)


def _mk_pipeline(cfg, params, n_stages=2):
    dev = jax.devices()[0]
    L = cfg.num_layers
    if n_stages == 1:
        split = [L]
    else:
        split = [max(1, L // n_stages)] * (n_stages - 1)
        split.append(L - sum(split))
    return AsymmetricPipeline(cfg, params, split, [[dev]] * len(split))


def _reqs(cfg, *, n, base_len=5, stride=3, out=5, arrivals=None):
    rng = np.random.RandomState(0)
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=base_len + stride * i
                                       ).astype(np.int32),
                    max_new_tokens=out,
                    arrival=0.0 if arrivals is None else arrivals[i])
            for i in range(n)]


@pytest.mark.parametrize("arch", ["granite-8b", "phi3.5-moe-42b-a6.6b"])
def test_pipeline_continuous_equals_isolated(arch):
    """Slot-continuous serving on a 2-stage asymmetric pipeline: each
    request's tokens match AsymmetricPipeline.generate run in isolation,
    including slot reuse (4 requests through 2 slots) and joint insertion
    of mixed-length prompts."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    pipe = _mk_pipeline(cfg, params, n_stages=2)
    reqs = _reqs(cfg, n=4)
    worker = PipelineBatcher(pipe, n_slots=2, max_len=48)
    stats = run_serve_loop([worker], reqs, deadline=1e9,
                           clock=VirtualClock())
    assert len(stats.latencies) == 4

    ref_pipe = _mk_pipeline(cfg, params, n_stages=2)
    for r in reqs:
        ref = ref_pipe.generate(r.prompt[None], max_new=r.max_new_tokens)
        assert list(r.output) == list(ref[0]), r.rid


def test_virtual_clock_determinism():
    """Same workload through fresh engines -> identical ServeStats, down to
    every latency value and iteration count."""
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    reqs0 = synth_workload(rate=200.0, duration=0.05, vocab=cfg.vocab_size,
                           prompt_len=6, prompt_jitter=4, out_len=4, seed=7)

    def run():
        router = Router([_mk_pipeline(cfg, params, n_stages=2),
                         _mk_pipeline(cfg, params, n_stages=1)],
                        n_slots=2, max_len=32)
        reqs = synth_workload(rate=200.0, duration=0.05,
                              vocab=cfg.vocab_size, prompt_len=6,
                              prompt_jitter=4, out_len=4, seed=7)
        return router.serve(reqs, 1e9, clock=VirtualClock())

    assert len(reqs0) >= 3          # workload actually exercises queueing
    s1, s2 = run(), run()
    assert s1.latencies == s2.latencies
    assert s1.queue_delays == s2.queue_delays
    assert s1.attainment == s2.attainment
    assert s1.throughput == s2.throughput
    assert s1.iterations == s2.iterations and s1.iterations > 0


def test_least_loaded_dispatch_spreads_replicas():
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    router = Router([_mk_pipeline(cfg, params, 1),
                     _mk_pipeline(cfg, params, 1)],
                    n_slots=1, max_len=32)
    reqs = _reqs(cfg, n=2, base_len=5, stride=0, out=3)
    router.serve(reqs, 1e9, clock=VirtualClock())
    # two single-slot replicas, two simultaneous arrivals: both admit at t=0
    assert [r.start_time for r in reqs] == [0.0, 0.0]

    solo = Router([_mk_pipeline(cfg, params, 1)], n_slots=1, max_len=32)
    reqs2 = _reqs(cfg, n=2, base_len=5, stride=0, out=3)
    solo.serve(reqs2, 1e9, clock=VirtualClock())
    # one slot total: the second request queues behind the first
    assert reqs2[0].start_time == 0.0 and reqs2[1].start_time > 0.0


def test_oversized_request_rejected_not_fatal():
    """A request that cannot fit prompt + decode steps in a slot is rejected
    alone (empty output, warning) instead of crashing the serve loop — even
    as the FIRST arrival, before any slot cache has been lazily allocated."""
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    pipe = _mk_pipeline(cfg, params, n_stages=2)
    worker = PipelineBatcher(pipe, n_slots=2, max_len=16)
    rng = np.random.RandomState(0)
    lens = [29, 5, 17]                      # oversized, ok, oversized
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=n
                                              ).astype(np.int32),
                    max_new_tokens=3, arrival=0.0)
            for i, n in enumerate(lens)]
    with pytest.warns(UserWarning, match="rejected with empty output"):
        stats = run_serve_loop([worker], reqs, deadline=1e9,
                               clock=VirtualClock())
    # rejected requests finish (empty output) but are NOT served: latency
    # percentiles and throughput cover only the one real completion
    assert len(stats.latencies) == 1
    assert stats.rejected == 2 and stats.dropped == 0
    assert [len(r.output) for r in reqs] == [0, 3, 0]


class _StrandingWorker:
    """Pathological worker: admits one request and then never runs it —
    busy() stays False, no future event. The loop must break out and the
    stranded request must surface as DROPPED, not as a negative latency
    that counts toward SLO attainment."""

    def __init__(self):
        self.req = None

    def capacity(self, now):
        return 0 if self.req else 1

    def load(self, now):
        return 0

    def admit(self, reqs, now):
        self.req = reqs[0]

    def busy(self, now):
        return False               # admitted work never becomes runnable

    def inflight(self):
        return 1 if self.req else 0

    def next_event(self, now):
        return None

    def run_iteration(self, now):
        raise AssertionError("never runnable")


def test_stranded_request_reported_dropped_not_attained():
    """Regression: a worker stranding an inflight request used to leave
    finish_time = 0.0, which produced a NEGATIVE latency that passed the
    deadline check and inflated attainment + throughput."""
    reqs = [Request(rid=0, prompt=np.zeros(3, np.int32), max_new_tokens=2,
                    arrival=0.0),
            Request(rid=1, prompt=np.zeros(3, np.int32), max_new_tokens=2,
                    arrival=0.5)]
    stats = run_serve_loop([_StrandingWorker()], reqs, deadline=1e9,
                           clock=VirtualClock())
    # rid 0 admitted then stranded; rid 1 never admitted (capacity 0):
    # both are dropped, neither contributes a latency, attainment is 0
    assert stats.dropped == 2
    assert stats.latencies == []
    assert stats.attainment == 0.0
    assert stats.throughput == 0.0
    assert all(r.finish_time is None for r in reqs)
    stats.summary()                # degenerate summary must not crash


def test_empty_and_all_rejected_stats_summary():
    """Regression: ServeStats.summary() crashed on np.percentile of an
    empty array when zero requests completed (e.g. an all-rejected
    replay)."""
    from repro.serving.loop import ServeStats
    s = ServeStats.from_requests([], deadline=1.0)
    assert s.attainment == 1.0 and s.latencies == []
    assert "n=0" in s.summary()
    # all-rejected: finished instantly with empty outputs
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new_tokens=3,
                    arrival=0.1 * i, output=np.zeros(0, np.int32),
                    start_time=0.1 * i, finish_time=0.1 * i + 1e-3)
            for i in range(3)]
    s2 = ServeStats.from_requests(reqs, deadline=1.0)
    assert s2.latencies == [] and s2.attainment == 0.0
    assert s2.throughput == 0.0
    assert "p50=n/a" in s2.summary()


def test_rejected_requests_excluded_from_throughput_and_percentiles():
    """Regression: rejected requests (near-instant empty completions) used
    to count toward throughput and drag p50/p99 toward zero."""
    from repro.serving.loop import ServeStats
    served = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                      arrival=0.0, output=np.array([1, 2], np.int32),
                      start_time=0.0, finish_time=10.0) for i in range(2)]
    rejected = [Request(rid=10 + i, prompt=np.zeros(99, np.int32),
                        max_new_tokens=2, arrival=0.0,
                        output=np.zeros(0, np.int32), start_time=0.0,
                        finish_time=0.001) for i in range(2)]
    stats = ServeStats.from_requests(served + rejected, deadline=1e9)
    assert stats.latencies == [10.0, 10.0]          # rejects excluded
    assert stats.throughput == pytest.approx(2 / 10.0)
    assert stats.attainment == pytest.approx(0.5)   # rejects not attained


def test_static_batcher_rejects_oversized_instead_of_crashing():
    """Satellite: StaticBatcher gets the same oversized-request guard the
    slot engines have — reject alone with an empty output, counted in
    ServeStats.rejected, instead of taking down the whole replay."""
    from repro.serving.router import StaticBatcher
    cfg = get_config("xlstm-125m").reduced()
    params = M.init_params(cfg, KEY)
    pipe = _mk_pipeline(cfg, params, n_stages=2)
    worker = StaticBatcher(pipe, max_batch=4, max_len=16)
    rng = np.random.RandomState(0)
    lens = [5, 29, 6]                       # ok, oversized, ok
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=n
                                              ).astype(np.int32),
                    max_new_tokens=3, arrival=0.0)
            for i, n in enumerate(lens)]
    with pytest.warns(UserWarning, match="rejected with empty output"):
        stats = run_serve_loop([worker], reqs, deadline=1e9,
                               clock=VirtualClock())
    assert stats.rejected == 1
    assert [len(r.output) for r in reqs] == [3, 0, 3]
    assert len(stats.latencies) == 2


class _StubWorker:
    """Single-slot compute worker: 3 iterations per request, cost 1.0."""

    def __init__(self):
        self.req, self.n = None, 0

    def capacity(self, now):
        return 0 if self.req else 1

    def load(self, now):
        return 1 if self.req else 0

    def admit(self, reqs, now):
        self.req, self.n = reqs[0], 3

    def busy(self, now):
        return self.req is not None

    def inflight(self):
        return 1 if self.req else 0

    def next_event(self, now):
        return None

    def run_iteration(self, now):
        self.n -= 1
        if self.n == 0:
            r, self.req = self.req, None
            return [(r, None, None)], 1.0
        return [], 1.0


def test_virtual_time_runs_replicas_in_parallel():
    """A virtual-clock cycle costs the SLOWEST busy worker's iteration, not
    the sum across replicas: two simultaneous requests on two single-slot
    replicas finish at t=3, exactly as one request on one replica would."""
    reqs = [Request(rid=i, prompt=np.zeros(1, np.int32), max_new_tokens=3,
                    arrival=0.0) for i in range(2)]
    run_serve_loop([_StubWorker(), _StubWorker()], reqs, deadline=1e9,
                   clock=VirtualClock())
    assert [r.latency for r in reqs] == [3.0, 3.0]


def test_analytic_worker_on_shared_loop():
    """The SLO simulator's analytic replicas run on the same loop with
    closed-form timing: request i admits every `bottleneck` and finishes
    `latency` later."""
    w = slo_sim.AnalyticWorker(slo_sim.ReplicaModel(latency=1.0,
                                                    bottleneck=0.25))
    reqs = [Request(rid=i, prompt=np.zeros(0, np.int32), max_new_tokens=0,
                    arrival=0.0) for i in range(4)]
    stats = run_serve_loop([w], reqs, deadline=1.6, clock=VirtualClock())
    fins = sorted(r.finish_time for r in reqs)
    assert fins == [1.0, 1.25, 1.5, 1.75]
    assert stats.attainment == 0.75          # 1.75 misses the 1.6 deadline


def test_simulate_matches_closed_form():
    """At rates far below 1/bottleneck every request should meet a deadline
    just above the latency, and miss one just below it."""
    reps = [slo_sim.ReplicaModel(latency=1.0, bottleneck=0.1)]
    assert slo_sim.simulate(reps, 0.2, 1.5, duration=30, seed=3) == 1.0
    assert slo_sim.simulate(reps, 0.2, 0.9, duration=30, seed=3) == 0.0
