"""Weight-only int8 quantization: fidelity, compression, decode path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.quant import (dequantize_leaf, mm, quant_bytes,
                                quantize_leaf, quantize_params)

KEY = jax.random.PRNGKey(0)


def test_leaf_roundtrip():
    w = jax.random.normal(KEY, (64, 32)) * 0.05
    q = quantize_leaf(w)
    back = dequantize_leaf(q)
    assert q["q"].dtype == jnp.int8
    assert q["s"].shape == (32,)
    # max error bounded by half a quantization step per out channel
    step = np.asarray(q["s"])
    assert (np.abs(np.asarray(back - w)).max(0) <= step * 0.51).all()


def test_mm_matches_dequant():
    w = jax.random.normal(KEY, (64, 32)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 64))
    q = quantize_leaf(w)
    np.testing.assert_allclose(np.asarray(mm(x, q)),
                               np.asarray(x @ dequantize_leaf(q)),
                               atol=1e-5)


def test_expert_leaf_scales_per_expert():
    w = jax.random.normal(KEY, (4, 16, 8)) * jnp.array(
        [0.01, 0.1, 1.0, 10.0])[:, None, None]
    q = quantize_leaf(w)
    assert q["s"].shape == (4, 8)
    # scales track the per-expert magnitudes
    assert float(q["s"][3].mean()) > 100 * float(q["s"][0].mean())


@pytest.mark.parametrize("arch", ["granite-8b", "phi3.5-moe-42b-a6.6b",
                                  "xlstm-125m"])
def test_quantized_model_fidelity(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = M.init_params(cfg, KEY)
    qparams = quantize_params(params, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    lg_f, _ = M.train_forward(cfg, params, batch)
    lg_q, _ = M.train_forward(cfg, qparams, batch)
    pf, pq = np.asarray(lg_f[:, -1]), np.asarray(lg_q[:, -1])
    # int8 quantization must preserve the argmax except for genuine near-
    # ties: when the fp top-2 margin is under 5% of the row's logit scale
    # the winner can legitimately flip under int8 noise (and XLA CPU thread
    # partitioning makes such ties nondeterministic). The exemption bound
    # deliberately depends only on the fp logits, so a regression that
    # inflates quantization error cannot widen its own tolerance.
    top2 = np.sort(pf, axis=-1)
    margin = top2[:, -1] - top2[:, -2]
    agree = pf.argmax(-1) == pq.argmax(-1)
    assert (agree | (margin < 0.05 * np.abs(pf).max(-1))).all()
    assert np.abs(pq - pf).max() / (np.abs(pf).max() + 1e-9) < 0.05
    assert quant_bytes(qparams) < 0.45 * quant_bytes(params)
    # decode path
    cache = M.init_cache(cfg, 2, 20)
    lg, cache = M.prefill(cfg, qparams, batch, cache)
    lg2, _ = M.decode_step(cfg, qparams, jnp.argmax(lg, -1), cache, 16)
    assert bool(jnp.isfinite(lg2).all())
